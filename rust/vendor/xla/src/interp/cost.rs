//! Compile-time cost model: picks the execution *strategy* for each
//! lowered op — never its *numerics*.
//!
//! Every dot algorithm in [`super::kernels`] implements one pinned
//! lane-accumulation contract (8 lane accumulators indexed `kk % 8`,
//! ascending `kk` within each lane, pairwise horizontal fold — see the
//! kernels module docs), so the selection made here affects wall-clock
//! only.  Canonical run records are byte-identical whichever variant runs,
//! at either interpreter tier, and the Python mirror needs exactly one dot
//! implementation.  The same holds for reduce: the grouped-lanes layout is
//! a detected property of the index map, and the lane walk is pinned.
//!
//! The inputs are the classic roofline terms available at compile time:
//! FLOPs (`2*m*n*k` for dot), bytes moved (operand + output traffic), and
//! the contiguity of the contraction strides (`l_kstride` / `r_kstride`)
//! plus the shape of the rhs free-index table (`r_base`).

/// Dot execution strategies.  All four produce bit-identical output (the
/// pinned lanes contract); they differ in traversal order and locality.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum DotAlgo {
    /// `r_kstride == 1`: per output element, 8-lane accumulation over
    /// contiguous k-slices of both operands (lhs slice gathered when
    /// `l_kstride != 1`).
    LanesContig,
    /// `l_kstride == 1 && r_kstride == 1` and enough columns: register
    /// block of [`super::kernels::NR`] output columns sharing each lhs
    /// load, one 8-lane accumulator file per column.
    LanesTiled,
    /// rhs free indices are exactly `0..n` (contiguous output columns,
    /// any `r_kstride`): k-outer pass, each k contributing an
    /// autovectorizable axpy into per-column lane scratch, columns tiled
    /// by [`super::kernels::TJ`] so the scratch stays in L1.
    AxpyLanes,
    /// Fully generic gather fallback (strided everything).  Also the only
    /// shape the scalar tier runs, for every plan.
    LanesGather,
}

/// Pick the dot strategy from compile-time layout facts.
///
/// `r_base_is_iota` means `r_base[j] == j` for all j — the rhs free
/// dimension walks contiguous columns, which is what lets an axpy pass
/// write `lanes[t][0..n]` with unit stride.
pub(crate) fn select_dot_algo(
    m: usize,
    n: usize,
    k: usize,
    l_kstride: usize,
    r_kstride: usize,
    r_base_is_iota: bool,
) -> DotAlgo {
    let flops = 2 * m * n * k;
    if r_kstride == 1 {
        // Contiguous rhs contraction: k-inner forms win — the k loop
        // streams both operands.  Tile only when the register block can
        // actually be refilled a useful number of times.
        if l_kstride == 1 && n >= super::kernels::NR && flops >= 2 * super::kernels::NR * 8 {
            DotAlgo::LanesTiled
        } else {
            DotAlgo::LanesContig
        }
    } else if r_base_is_iota && n > 1 {
        // Strided contraction but contiguous output columns: bytes moved
        // per k element are minimized by the k-outer axpy (one lhs scalar
        // broadcast against a unit-stride rhs row segment).
        DotAlgo::AxpyLanes
    } else {
        DotAlgo::LanesGather
    }
}

/// Convolution execution strategies.  Both produce bit-identical output:
/// the blocked kernel walks the exact same patch-column contraction order
/// under the pinned lanes contract, it just never materializes the patch
/// matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum ConvAlgo {
    /// Fused blocked-direct kernel: register block of
    /// [`super::kernels::NR`] output channels (mirroring `LanesTiled`),
    /// patch tiles gathered straight from the lhs buffer through the
    /// precomputed map into 8-lane registers, weights pre-gathered into a
    /// stack tile per column block.  No `[m, k]` patch materialization,
    /// no shared conv scratch.
    Blocked,
    /// Materialize the full im2col patch matrix into the shared scratch
    /// and replay the cost-model-picked dot plan (the original path; the
    /// fallback arm).
    Im2col,
}

/// Patch-matrix footprint (in f32 elements, `groups * m * k`) above which
/// the im2col materialization stops being a cache-resident copy and
/// becomes a real memory-traffic pass worth eliminating.  16 Ki floats =
/// 64 KiB — twice a typical L1d, so the patch write + dot re-read both
/// stream.
pub(crate) const CONV_BLOCKED_MIN_FOOTPRINT: usize = 16 * 1024;

/// Pick the convolution strategy from compile-time geometry.
///
/// The blocked kernel earns its keep through two reuse terms:
///
/// * **column reuse** — each gathered 8-lane patch chunk feeds
///   [`super::kernels::NR`] output channels, so it needs `ng >= NR` per
///   group to refill the register block (weight-gradient convs lowered as
///   `convolution` have tiny `ng` per group and stay on im2col);
/// * **arithmetic intensity / patch reuse** — overlapping windows make
///   the im2col patch matrix (`groups * m * k` floats) larger than the
///   lhs it was gathered from; once that footprint exceeds
///   [`CONV_BLOCKED_MIN_FOOTPRINT`] the materialize-then-stream pass is
///   the dominant traffic and blocked-direct wins.  Below it everything
///   is L1-resident and the shared dot plans are already tight.
///
/// Strategy only — the pinned lanes contract means the choice never
/// affects bits.
pub(crate) fn select_conv_algo(m: usize, k: usize, ng: usize, groups: usize) -> ConvAlgo {
    if ng >= super::kernels::NR && groups * m * k >= CONV_BLOCKED_MIN_FOOTPRINT {
        ConvAlgo::Blocked
    } else {
        ConvAlgo::Im2col
    }
}

/// Reduce execution strategies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum ReduceAlgo {
    /// Add region whose index map is grouped-contiguous
    /// (`map[i] == i / group` for all i): per output element, 8-lane
    /// accumulation over its `group` consecutive inputs with the pinned
    /// fold.  This is the only reduce shape whose numeric order differs
    /// from the flat walk, and both tiers + the mirror implement it.
    GroupedLanes { group: usize },
    /// Everything else: the original flat-ascending walk (bit-identical
    /// to the tree-walk reference evaluator).
    Flat,
}

/// Detect the grouped-contiguous layout.  `is_add` gates the lanes path
/// to the commutative-friendly Add region; Mul/Max/Min/Program regions
/// keep the reference-order flat walk unchanged.
pub(crate) fn select_reduce_algo(map: &[u32], out_elems: usize, is_add: bool) -> ReduceAlgo {
    if !is_add || out_elems == 0 || map.is_empty() || !map.len().is_multiple_of(out_elems) {
        return ReduceAlgo::Flat;
    }
    let group = map.len() / out_elems;
    let grouped = map
        .iter()
        .enumerate()
        .all(|(i, &of)| of as usize == i / group);
    if grouped {
        ReduceAlgo::GroupedLanes { group }
    } else {
        ReduceAlgo::Flat
    }
}

/// Fusion caps for a fused loop over `n` elements: `(max ops, max
/// inputs)`.  Derived from an L1 scratch budget — each fused op owns a
/// `BLOCK`-wide f32 scratch register, and the whole register file plus one
/// cache line per distinct input stream should sit in L1 while the loop
/// runs.  Fusing is numerics-free (elementwise, same per-element order),
/// so these caps are pure strategy; they can never exceed the structural
/// ceilings [`super::program::MAX_FUSED_OPS`] /
/// [`super::program::MAX_FUSED_INPUTS`] that size the stack register file.
pub(crate) fn fusion_caps(n: usize) -> (usize, usize) {
    // Budget half of a typical 32 KiB L1d for the op scratch file
    // (BLOCK f32s per fused op), the other half for streamed inputs.
    const L1D_BYTES: usize = 32 * 1024;
    let per_reg = super::kernels::BLOCK * core::mem::size_of::<f32>();
    let ops = ((L1D_BYTES / 2) / per_reg).min(super::program::MAX_FUSED_OPS);
    // A loop that fits in one block (n <= BLOCK) never streams, so only
    // the structural ceiling applies; longer loops get one resident block
    // per distinct input plus one for the output.
    let inputs = if n <= super::kernels::BLOCK {
        super::program::MAX_FUSED_INPUTS
    } else {
        ((L1D_BYTES / 2) / per_reg - 1).min(super::program::MAX_FUSED_INPUTS)
    };
    (ops, inputs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_selection_matches_layout() {
        // steplogreg8 train_div_b64 forward dots: f32[64,8] x f32[8].
        assert_eq!(select_dot_algo(64, 1, 8, 1, 1, true), DotAlgo::LanesContig);
        // Gradient dot: f32[64] x f32[64,8] contracting dim 0 of both —
        // r_kstride = 8, r_base = 0..8.
        assert_eq!(select_dot_algo(1, 8, 64, 1, 8, true), DotAlgo::AxpyLanes);
        // Wide contiguous matmul: register-blocked tiles.
        assert_eq!(select_dot_algo(16, 16, 32, 1, 1, true), DotAlgo::LanesTiled);
        // Strided rhs with a non-iota base table: generic gather.
        assert_eq!(
            select_dot_algo(4, 4, 16, 2, 3, false),
            DotAlgo::LanesGather
        );
        // Single strided column: axpy has nothing to vectorize over.
        assert_eq!(select_dot_algo(8, 1, 16, 1, 4, true), DotAlgo::LanesGather);
    }

    #[test]
    fn conv_selection_needs_column_reuse_and_footprint() {
        // tinyresnet8-class forward conv: b8, 16x16 output, k=3*3*8=72,
        // 16 output channels — big footprint, wide channels: blocked.
        assert_eq!(select_conv_algo(2048, 72, 16, 1), ConvAlgo::Blocked);
        // Weight-gradient conv lowered as convolution: ng per group is 1
        // regardless of footprint — stays on im2col.
        assert_eq!(select_conv_algo(72, 2048, 1, 8), ConvAlgo::Im2col);
        // Narrow channel count (< NR) can't refill the register block.
        assert_eq!(select_conv_algo(4096, 64, 3, 1), ConvAlgo::Im2col);
        // Small cache-resident conv: the im2col copy is free enough.
        assert_eq!(select_conv_algo(64, 27, 8, 1), ConvAlgo::Im2col);
        // Grouped conv: footprint counts every group's patch pass.
        assert_eq!(select_conv_algo(512, 18, 4, 2), ConvAlgo::Blocked);
        assert_eq!(select_conv_algo(512, 18, 4, 1), ConvAlgo::Im2col);
    }

    #[test]
    fn reduce_selection_requires_grouped_add() {
        // [64,8] -> [64] over the trailing dim: map[i] = i / 8.
        let map: Vec<u32> = (0..512).map(|i| i / 8).collect();
        assert_eq!(
            select_reduce_algo(&map, 64, true),
            ReduceAlgo::GroupedLanes { group: 8 }
        );
        // Same map, non-Add region: flat.
        assert_eq!(select_reduce_algo(&map, 64, false), ReduceAlgo::Flat);
        // Full reduction to a scalar is grouped with group = len.
        let all: Vec<u32> = vec![0; 64];
        assert_eq!(
            select_reduce_algo(&all, 1, true),
            ReduceAlgo::GroupedLanes { group: 64 }
        );
        // Leading-dim reduction interleaves outputs: flat.
        let interleaved: Vec<u32> = (0..512).map(|i| i % 8).collect();
        assert_eq!(select_reduce_algo(&interleaved, 8, true), ReduceAlgo::Flat);
        // Degenerate group size 1 is still grouped (identity sum).
        let ident: Vec<u32> = (0..64).collect();
        assert_eq!(
            select_reduce_algo(&ident, 64, true),
            ReduceAlgo::GroupedLanes { group: 1 }
        );
        assert_eq!(select_reduce_algo(&[], 0, true), ReduceAlgo::Flat);
    }

    #[test]
    fn fusion_caps_stay_within_structural_ceilings() {
        for n in [0, 1, 63, 64, 65, 4096] {
            let (ops, inputs) = fusion_caps(n);
            assert!(ops >= 1 && ops <= crate::interp::program::MAX_FUSED_OPS);
            assert!(inputs >= 1 && inputs <= crate::interp::program::MAX_FUSED_INPUTS);
        }
    }
}
