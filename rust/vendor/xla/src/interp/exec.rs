//! The execute phase: run a compiled [`Program`] over a reusable buffer
//! arena.
//!
//! Per call: pop an [`Arena`] from the program's pool (or build one, once
//! per concurrent caller), run the steps — each a typed kernel over slot
//! slices — and hand the arena back.  Slots were sized to their largest
//! occupant at compile time, so a steady-state training step performs
//! **zero** buffer allocation; the only per-call allocations are the
//! output `Literal`s themselves.  Argument `Literal`s are borrowed — their
//! data feeds kernels directly, never cloned.
//!
//! The pool is behind a `Mutex`, taken exactly twice per call (pop/push),
//! never inside the step loop; concurrent trial-engine workers each end
//! up with their own arena.  The pool is capped so a burst of workers
//! cannot pin unbounded memory.

use std::sync::atomic::Ordering;

use super::cost;
use super::kernels;
use super::parse::{elements, err, DType};
use super::program::{ParamSpec, Program, Ref, SlotSpec, Step};
use crate::{Data, InterpTier, Literal, Result};

/// A borrowed argument buffer: entry `Literal` data, or a sub-program's
/// call-site / loop-carried view.  Pred arguments exist only on the
/// sub-program path (entry pred parameters are rejected at compile time).
#[derive(Clone, Copy, Debug)]
pub(crate) enum ArgView<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    Pred(&'a [bool]),
}

/// An owned result buffer (sub-program outputs, loop-carried state).
#[derive(Clone, Debug)]
pub(crate) enum OwnBuf {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Pred(Vec<bool>),
}

impl OwnBuf {
    fn view(&self) -> ArgView<'_> {
        match self {
            OwnBuf::F32(v) => ArgView::F32(v),
            OwnBuf::I32(v) => ArgView::I32(v),
            OwnBuf::Pred(v) => ArgView::Pred(v),
        }
    }
}

/// Max arenas kept for reuse (beyond this, returned arenas are dropped).
const POOL_CAP: usize = 16;

/// One 32-byte-aligned group of 8 f32 lanes (size 32, no padding): the
/// allocation unit of f32 slot buffers, so an 8-wide lane load starting
/// at a slot base never straddles a cache-line boundary.
#[repr(C, align(32))]
#[derive(Clone, Copy, Debug)]
struct Lane8([f32; kernels::LANES]);

/// An f32 slot buffer backed by [`Lane8`] groups.  Derefs to `[f32]` of
/// the logical length, so kernels and call sites see a plain slice; the
/// backing allocation is always 32-byte aligned and a whole number of
/// lane groups.
#[derive(Debug)]
pub(crate) struct AlignedF32 {
    lanes: Vec<Lane8>,
    len: usize,
}

impl AlignedF32 {
    fn zeroed(len: usize) -> AlignedF32 {
        AlignedF32 {
            lanes: vec![Lane8([0.0; kernels::LANES]); len.div_ceil(kernels::LANES)],
            len,
        }
    }

    fn grow(&mut self, len: usize) {
        self.lanes
            .resize(len.div_ceil(kernels::LANES), Lane8([0.0; kernels::LANES]));
        self.len = len;
    }
}

impl std::ops::Deref for AlignedF32 {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        // SAFETY: Lane8 is repr(C, align(32)) over [f32; 8] — size 32, no
        // padding — so `lanes` is a contiguous run of at least `len` f32s.
        unsafe { std::slice::from_raw_parts(self.lanes.as_ptr().cast::<f32>(), self.len) }
    }
}

impl std::ops::DerefMut for AlignedF32 {
    fn deref_mut(&mut self) -> &mut [f32] {
        // SAFETY: as in `deref`.
        unsafe {
            std::slice::from_raw_parts_mut(self.lanes.as_mut_ptr().cast::<f32>(), self.len)
        }
    }
}

/// One execution scratch space: a buffer per compiled slot.
#[derive(Debug)]
pub(crate) struct Arena {
    bufs: Vec<ArenaBuf>,
}

#[derive(Debug)]
enum ArenaBuf {
    F32(AlignedF32),
    I32(Vec<i32>),
    Pred(Vec<bool>),
}

impl Arena {
    fn for_slots(slots: &[SlotSpec]) -> Arena {
        Arena {
            bufs: slots
                .iter()
                .map(|s| match s.dtype {
                    DType::F32 => ArenaBuf::F32(AlignedF32::zeroed(s.max_elems)),
                    DType::S32 => ArenaBuf::I32(vec![0; s.max_elems]),
                    DType::Pred => ArenaBuf::Pred(vec![false; s.max_elems]),
                })
                .collect(),
        }
    }
}

fn internal(msg: &str) -> crate::Error {
    err(format!("interp internal error: {msg} (compile-time typing should prevent this)"))
}

impl Program {
    /// [`Program::execute_with_tier`] at the process-default tier
    /// (`DIVEBATCH_INTERP_TIER`, read once).
    pub(crate) fn execute(&self, args: &[&Literal]) -> Result<Literal> {
        self.execute_with_tier(args, InterpTier::from_env())
    }

    /// Validate `args` against the entry parameters, then run the steps
    /// at an explicit tier.  Both tiers produce identical bits (the
    /// pinned lanes contract — see [`super::kernels`]); the tier picks
    /// the execution strategy only.
    pub(crate) fn execute_with_tier(&self, args: &[&Literal], tier: InterpTier) -> Result<Literal> {
        if args.len() != self.params.len() {
            return Err(err(format!(
                "entry {:?} takes {} parameters, got {} arguments",
                self.entry_name,
                self.params.len(),
                args.len()
            )));
        }
        for (i, (lit, spec)) in args.iter().zip(&self.params).enumerate() {
            let (data, dims) = lit.dense_parts().ok_or_else(|| {
                err("tuple arguments are not supported".to_string())
            })?;
            let got_dt = match data {
                Data::F32(_) => DType::F32,
                Data::I32(_) => DType::S32,
            };
            let dims_u: Vec<usize> = dims
                .iter()
                .map(|&d| {
                    if d < 0 {
                        Err(err(format!("negative dimension {d} in argument")))
                    } else {
                        Ok(d as usize)
                    }
                })
                .collect::<Result<_>>()?;
            if dims_u != spec.dims || got_dt != spec.dtype {
                let want_dims: Vec<String> = spec.dims.iter().map(|d| d.to_string()).collect();
                let got_dims: Vec<String> = dims_u.iter().map(|d| d.to_string()).collect();
                return Err(err(format!(
                    "argument {i} ({}): expected {}[{}], got {got_dt}[{}]",
                    spec.name,
                    spec.dtype,
                    want_dims.join(","),
                    got_dims.join(",")
                )));
            }
            let want_elems: usize = spec.dims.iter().product();
            let got_elems = match data {
                Data::F32(v) => v.len(),
                Data::I32(v) => v.len(),
            };
            if got_elems != want_elems {
                return Err(err(format!(
                    "argument has {got_elems} elements but dims {dims_u:?}"
                )));
            }
        }

        let views: Vec<ArgView> = args
            .iter()
            .map(|lit| match lit.dense_parts() {
                Some((Data::F32(v), _)) => ArgView::F32(v),
                Some((Data::I32(v), _)) => ArgView::I32(v),
                None => unreachable!("validated above"),
            })
            .collect();
        let mut arena = self.pop_arena();
        let result = self
            .run_steps(&views, &mut arena, tier)
            .and_then(|()| self.collect_outputs(&views, &arena));
        self.push_arena(arena);
        result
    }

    /// Run over already-validated raw argument views and return owned
    /// output buffers — the sub-program path (`call`, `while`).  Argument
    /// shapes were checked against the callee's parameters at compile
    /// time, so no per-call `Literal` validation happens here.
    pub(crate) fn execute_raw(&self, args: &[ArgView], tier: InterpTier) -> Result<Vec<OwnBuf>> {
        debug_assert_eq!(args.len(), self.params.len());
        let mut arena = self.pop_arena();
        let result = self
            .run_steps(args, &mut arena, tier)
            .and_then(|()| self.collect_raw(args, &arena));
        self.push_arena(arena);
        result
    }

    fn pop_arena(&self) -> Arena {
        let popped = {
            let mut pool = self.pool.lock().unwrap_or_else(|e| e.into_inner());
            pool.pop()
        };
        match popped {
            Some(a) => a,
            None => {
                self.arenas_created.fetch_add(1, Ordering::Relaxed);
                Arena::for_slots(&self.slots)
            }
        }
    }

    fn push_arena(&self, arena: Arena) {
        let mut pool = self.pool.lock().unwrap_or_else(|e| e.into_inner());
        if pool.len() < POOL_CAP {
            pool.push(arena);
        }
    }

    /// (arenas created, buffers grown) — the bench's allocs-proxy.
    pub(crate) fn arena_stats(&self) -> (u64, u64) {
        (
            self.arenas_created.load(Ordering::Relaxed),
            self.buffers_grown.load(Ordering::Relaxed),
        )
    }

    fn run_steps(&self, args: &[ArgView], arena: &mut Arena, tier: InterpTier) -> Result<()> {
        // Grow any undersized buffer (only possible if an arena outlived a
        // recompile — counted as the allocs-proxy's "grow" channel).
        for (buf, spec) in arena.bufs.iter_mut().zip(&self.slots) {
            let len = match buf {
                ArenaBuf::F32(v) => v.len(),
                ArenaBuf::I32(v) => v.len(),
                ArenaBuf::Pred(v) => v.len(),
            };
            if len < spec.max_elems {
                self.buffers_grown.fetch_add(1, Ordering::Relaxed);
                match buf {
                    ArenaBuf::F32(v) => v.grow(spec.max_elems),
                    ArenaBuf::I32(v) => v.resize(spec.max_elems, 0),
                    ArenaBuf::Pred(v) => v.resize(spec.max_elems, false),
                }
            }
        }
        for step in &self.steps {
            self.run_step(step, args, arena, tier)?;
        }
        Ok(())
    }

    // ---------------------------------------------------- source views

    fn f32_src<'a>(
        &'a self,
        r: Ref,
        args: &'a [ArgView<'a>],
        arena: &'a Arena,
    ) -> Result<&'a [f32]> {
        match r {
            Ref::Slot(s) => match &arena.bufs[s as usize] {
                ArenaBuf::F32(v) => Ok(&v[..]),
                _ => Err(internal("slot dtype mismatch (f32)")),
            },
            Ref::Param(p) => match args[p as usize] {
                ArgView::F32(v) => Ok(v),
                _ => Err(internal("param dtype mismatch (f32)")),
            },
            Ref::Const(c) => match &self.consts[c as usize] {
                super::program::ConstBuf::F32(v) => Ok(v),
                _ => Err(internal("const dtype mismatch (f32)")),
            },
        }
    }

    fn i32_src<'a>(
        &'a self,
        r: Ref,
        args: &'a [ArgView<'a>],
        arena: &'a Arena,
    ) -> Result<&'a [i32]> {
        match r {
            Ref::Slot(s) => match &arena.bufs[s as usize] {
                ArenaBuf::I32(v) => Ok(v),
                _ => Err(internal("slot dtype mismatch (i32)")),
            },
            Ref::Param(p) => match args[p as usize] {
                ArgView::I32(v) => Ok(v),
                _ => Err(internal("param dtype mismatch (i32)")),
            },
            Ref::Const(c) => match &self.consts[c as usize] {
                super::program::ConstBuf::I32(v) => Ok(v),
                _ => Err(internal("const dtype mismatch (i32)")),
            },
        }
    }

    fn pred_src<'a>(
        &'a self,
        r: Ref,
        args: &'a [ArgView<'a>],
        arena: &'a Arena,
    ) -> Result<&'a [bool]> {
        match r {
            Ref::Slot(s) => match &arena.bufs[s as usize] {
                ArenaBuf::Pred(v) => Ok(v),
                _ => Err(internal("slot dtype mismatch (pred)")),
            },
            // Entry pred parameters are rejected at compile time; this arm
            // serves sub-programs (while state, call operands).
            Ref::Param(p) => match args[p as usize] {
                ArgView::Pred(v) => Ok(v),
                _ => Err(internal("param dtype mismatch (pred)")),
            },
            Ref::Const(c) => match &self.consts[c as usize] {
                super::program::ConstBuf::Pred(v) => Ok(v),
                _ => Err(internal("const dtype mismatch (pred)")),
            },
        }
    }

    /// Borrow `r` as an [`ArgView`] of `spec`'s dtype, sliced to the
    /// callee parameter's exact element count (slot buffers can be wider
    /// than the logical value they currently hold).
    fn view_of<'a>(
        &'a self,
        r: Ref,
        spec: &ParamSpec,
        args: &'a [ArgView<'a>],
        arena: &'a Arena,
    ) -> Result<ArgView<'a>> {
        let n = elements(&spec.dims);
        Ok(match spec.dtype {
            DType::F32 => ArgView::F32(&self.f32_src(r, args, arena)?[..n]),
            DType::S32 => ArgView::I32(&self.i32_src(r, args, arena)?[..n]),
            DType::Pred => ArgView::Pred(&self.pred_src(r, args, arena)?[..n]),
        })
    }

    /// Copy `r` into an owned buffer of `spec`'s dtype (initial while
    /// state, which must outlive mutations of the parent arena).
    fn own_of(
        &self,
        r: Ref,
        spec: &ParamSpec,
        args: &[ArgView],
        arena: &Arena,
    ) -> Result<OwnBuf> {
        let n = elements(&spec.dims);
        Ok(match spec.dtype {
            DType::F32 => OwnBuf::F32(self.f32_src(r, args, arena)?[..n].to_vec()),
            DType::S32 => OwnBuf::I32(self.i32_src(r, args, arena)?[..n].to_vec()),
            DType::Pred => OwnBuf::Pred(self.pred_src(r, args, arena)?[..n].to_vec()),
        })
    }

    /// Write a sub-program's owned results into this program's slots.
    fn store_results(&self, results: Vec<OwnBuf>, outs: &[u32], arena: &mut Arena) -> Result<()> {
        if results.len() != outs.len() {
            return Err(internal("sub-program output arity mismatch"));
        }
        for (buf, &slot) in results.into_iter().zip(outs) {
            match (buf, &mut arena.bufs[slot as usize]) {
                (OwnBuf::F32(v), ArenaBuf::F32(dst)) => dst[..v.len()].copy_from_slice(&v),
                (OwnBuf::I32(v), ArenaBuf::I32(dst)) => dst[..v.len()].copy_from_slice(&v),
                (OwnBuf::Pred(v), ArenaBuf::Pred(dst)) => dst[..v.len()].copy_from_slice(&v),
                _ => return Err(internal("sub-program output dtype mismatch")),
            }
        }
        Ok(())
    }

    /// Read the scalar s32 start indices of a dynamic-slice/-update and
    /// clamp each to `[0, src_dim - window_dim]` (the HLO contract).
    fn start_offsets(
        &self,
        starts: &[Ref],
        src_dims: &[usize],
        window: &[usize],
        args: &[ArgView],
        arena: &Arena,
    ) -> Result<Vec<usize>> {
        let mut offs = Vec::with_capacity(starts.len());
        for (d, &r) in starts.iter().enumerate() {
            let v = i64::from(self.i32_src(r, args, arena)?[0]);
            let max = (src_dims[d] - window[d]) as i64;
            offs.push(v.clamp(0, max) as usize);
        }
        Ok(offs)
    }

    // ------------------------------------------------------- out buffers

    fn take_f32(&self, arena: &mut Arena, slot: u32) -> Result<AlignedF32> {
        match std::mem::replace(
            &mut arena.bufs[slot as usize],
            ArenaBuf::F32(AlignedF32::zeroed(0)),
        ) {
            ArenaBuf::F32(v) => Ok(v),
            other => {
                arena.bufs[slot as usize] = other;
                Err(internal("out slot dtype mismatch (f32)"))
            }
        }
    }

    fn take_i32(&self, arena: &mut Arena, slot: u32) -> Result<Vec<i32>> {
        match std::mem::replace(&mut arena.bufs[slot as usize], ArenaBuf::I32(Vec::new())) {
            ArenaBuf::I32(v) => Ok(v),
            other => {
                arena.bufs[slot as usize] = other;
                Err(internal("out slot dtype mismatch (i32)"))
            }
        }
    }

    fn take_pred(&self, arena: &mut Arena, slot: u32) -> Result<Vec<bool>> {
        match std::mem::replace(&mut arena.bufs[slot as usize], ArenaBuf::Pred(Vec::new())) {
            ArenaBuf::Pred(v) => Ok(v),
            other => {
                arena.bufs[slot as usize] = other;
                Err(internal("out slot dtype mismatch (pred)"))
            }
        }
    }

    // ------------------------------------------------------------ steps

    fn run_step(
        &self,
        step: &Step,
        args: &[ArgView],
        arena: &mut Arena,
        tier: InterpTier,
    ) -> Result<()> {
        match step {
            Step::Fused(f) => {
                let mut out = self.take_f32(arena, f.out)?;
                const EMPTY: &[f32] = &[];
                let mut ins: [&[f32]; super::program::MAX_FUSED_INPUTS] =
                    [EMPTY; super::program::MAX_FUSED_INPUTS];
                let mut ok = Ok(());
                for (slot, &r) in ins.iter_mut().zip(&f.inputs) {
                    match self.f32_src(r, args, arena) {
                        Ok(v) => *slot = v,
                        Err(e) => {
                            ok = Err(e);
                            break;
                        }
                    }
                }
                if ok.is_ok() {
                    kernels::run_fused(f, &ins[..f.inputs.len()], &mut out[..f.n], tier);
                }
                arena.bufs[f.out as usize] = ArenaBuf::F32(out);
                ok
            }
            Step::IntEw { op, a, b, out, n } => {
                let mut o = self.take_i32(arena, *out)?;
                let res = (|| {
                    let av = self.i32_src(*a, args, arena)?;
                    match b {
                        None => kernels::int_unary(*op, &av[..*n], &mut o[..*n]),
                        Some(b) => {
                            let bv = self.i32_src(*b, args, arena)?;
                            kernels::int_binary(*op, &av[..*n], &bv[..*n], &mut o[..*n]);
                        }
                    }
                    Ok(())
                })();
                arena.bufs[*out as usize] = ArenaBuf::I32(o);
                res
            }
            Step::PredEw { op, a, b, out, n } => {
                let mut o = self.take_pred(arena, *out)?;
                let res = (|| {
                    let av = self.pred_src(*a, args, arena)?;
                    match b {
                        None => kernels::pred_unary(*op, &av[..*n], &mut o[..*n]),
                        Some(b) => {
                            let bv = self.pred_src(*b, args, arena)?;
                            kernels::pred_binary(*op, &av[..*n], &bv[..*n], &mut o[..*n]);
                        }
                    }
                    Ok(())
                })();
                arena.bufs[*out as usize] = ArenaBuf::Pred(o);
                res
            }
            Step::Compare {
                dir,
                dtype,
                a,
                b,
                out,
                n,
            } => {
                let mut o = self.take_pred(arena, *out)?;
                let res = (|| {
                    match dtype {
                        DType::F32 => {
                            let av = self.f32_src(*a, args, arena)?;
                            let bv = self.f32_src(*b, args, arena)?;
                            kernels::compare_f32(*dir, &av[..*n], &bv[..*n], &mut o[..*n]);
                        }
                        DType::S32 => {
                            let av = self.i32_src(*a, args, arena)?;
                            let bv = self.i32_src(*b, args, arena)?;
                            kernels::compare_i32(*dir, &av[..*n], &bv[..*n], &mut o[..*n]);
                        }
                        DType::Pred => {
                            let av = self.pred_src(*a, args, arena)?;
                            let bv = self.pred_src(*b, args, arena)?;
                            kernels::compare_pred(*dir, &av[..*n], &bv[..*n], &mut o[..*n]);
                        }
                    }
                    Ok(())
                })();
                arena.bufs[*out as usize] = ArenaBuf::Pred(o);
                res
            }
            Step::Select {
                dtype,
                p,
                t,
                f,
                out,
                n,
                scalar_pred,
            } => {
                let pn = if *scalar_pred { 1 } else { *n };
                match dtype {
                    DType::F32 => {
                        let mut o = self.take_f32(arena, *out)?;
                        let res = (|| {
                            let pv = self.pred_src(*p, args, arena)?;
                            let tv = self.f32_src(*t, args, arena)?;
                            let fv = self.f32_src(*f, args, arena)?;
                            kernels::select(
                                &pv[..pn],
                                *scalar_pred,
                                &tv[..*n],
                                &fv[..*n],
                                &mut o[..*n],
                            );
                            Ok(())
                        })();
                        arena.bufs[*out as usize] = ArenaBuf::F32(o);
                        res
                    }
                    DType::S32 => {
                        let mut o = self.take_i32(arena, *out)?;
                        let res = (|| {
                            let pv = self.pred_src(*p, args, arena)?;
                            let tv = self.i32_src(*t, args, arena)?;
                            let fv = self.i32_src(*f, args, arena)?;
                            kernels::select(
                                &pv[..pn],
                                *scalar_pred,
                                &tv[..*n],
                                &fv[..*n],
                                &mut o[..*n],
                            );
                            Ok(())
                        })();
                        arena.bufs[*out as usize] = ArenaBuf::I32(o);
                        res
                    }
                    DType::Pred => {
                        let mut o = self.take_pred(arena, *out)?;
                        let res = (|| {
                            let pv = self.pred_src(*p, args, arena)?;
                            let tv = self.pred_src(*t, args, arena)?;
                            let fv = self.pred_src(*f, args, arena)?;
                            kernels::select(
                                &pv[..pn],
                                *scalar_pred,
                                &tv[..*n],
                                &fv[..*n],
                                &mut o[..*n],
                            );
                            Ok(())
                        })();
                        arena.bufs[*out as usize] = ArenaBuf::Pred(o);
                        res
                    }
                }
            }
            Step::Convert {
                from,
                to,
                a,
                out,
                n,
            } => self.run_convert(*from, *to, *a, *out, *n, args, arena),
            Step::Gather {
                dtype,
                src,
                map,
                out,
            } => match dtype {
                DType::F32 => {
                    let mut o = self.take_f32(arena, *out)?;
                    let res = self.f32_src(*src, args, arena).map(|s| {
                        kernels::gather(s, map, &mut o[..map.len()]);
                    });
                    arena.bufs[*out as usize] = ArenaBuf::F32(o);
                    res
                }
                DType::S32 => {
                    let mut o = self.take_i32(arena, *out)?;
                    let res = self.i32_src(*src, args, arena).map(|s| {
                        kernels::gather(s, map, &mut o[..map.len()]);
                    });
                    arena.bufs[*out as usize] = ArenaBuf::I32(o);
                    res
                }
                DType::Pred => {
                    let mut o = self.take_pred(arena, *out)?;
                    let res = self.pred_src(*src, args, arena).map(|s| {
                        kernels::gather(s, map, &mut o[..map.len()]);
                    });
                    arena.bufs[*out as usize] = ArenaBuf::Pred(o);
                    res
                }
            },
            Step::Pad {
                dtype,
                src,
                fill,
                map,
                out,
            } => match dtype {
                DType::F32 => {
                    let mut o = self.take_f32(arena, *out)?;
                    let res = (|| {
                        let s = self.f32_src(*src, args, arena)?;
                        let fv = self.f32_src(*fill, args, arena)?[0];
                        kernels::pad(s, fv, map, &mut o[..map.len()]);
                        Ok(())
                    })();
                    arena.bufs[*out as usize] = ArenaBuf::F32(o);
                    res
                }
                DType::S32 => {
                    let mut o = self.take_i32(arena, *out)?;
                    let res = (|| {
                        let s = self.i32_src(*src, args, arena)?;
                        let fv = self.i32_src(*fill, args, arena)?[0];
                        kernels::pad(s, fv, map, &mut o[..map.len()]);
                        Ok(())
                    })();
                    arena.bufs[*out as usize] = ArenaBuf::I32(o);
                    res
                }
                DType::Pred => {
                    let mut o = self.take_pred(arena, *out)?;
                    let res = (|| {
                        let s = self.pred_src(*src, args, arena)?;
                        let fv = self.pred_src(*fill, args, arena)?[0];
                        kernels::pad(s, fv, map, &mut o[..map.len()]);
                        Ok(())
                    })();
                    arena.bufs[*out as usize] = ArenaBuf::Pred(o);
                    res
                }
            },
            Step::Concat {
                dtype,
                parts,
                out,
                n,
            } => match dtype {
                DType::F32 => {
                    let mut o = self.take_f32(arena, *out)?;
                    let res = (|| {
                        for (r, place) in parts {
                            let s = self.f32_src(*r, args, arena)?;
                            kernels::scatter_part(&s[..place.len()], place, &mut o[..*n]);
                        }
                        Ok(())
                    })();
                    arena.bufs[*out as usize] = ArenaBuf::F32(o);
                    res
                }
                DType::S32 => {
                    let mut o = self.take_i32(arena, *out)?;
                    let res = (|| {
                        for (r, place) in parts {
                            let s = self.i32_src(*r, args, arena)?;
                            kernels::scatter_part(&s[..place.len()], place, &mut o[..*n]);
                        }
                        Ok(())
                    })();
                    arena.bufs[*out as usize] = ArenaBuf::I32(o);
                    res
                }
                DType::Pred => {
                    let mut o = self.take_pred(arena, *out)?;
                    let res = (|| {
                        for (r, place) in parts {
                            let s = self.pred_src(*r, args, arena)?;
                            kernels::scatter_part(&s[..place.len()], place, &mut o[..*n]);
                        }
                        Ok(())
                    })();
                    arena.bufs[*out as usize] = ArenaBuf::Pred(o);
                    res
                }
            },
            Step::Dot(p) => {
                let mut o = self.take_f32(arena, p.out)?;
                let res = (|| {
                    let l = self.f32_src(p.lhs, args, arena)?;
                    let r = self.f32_src(p.rhs, args, arena)?;
                    for bx in 0..p.b {
                        kernels::dot(
                            tier,
                            p.algo,
                            l,
                            r,
                            &p.l_base[bx * p.m..][..p.m],
                            &p.r_base[bx * p.n..][..p.n],
                            p.l_kstride,
                            p.r_kstride,
                            p.k,
                            &mut o[bx * p.m * p.n..][..p.m * p.n],
                        );
                    }
                    Ok(())
                })();
                arena.bufs[p.out as usize] = ArenaBuf::F32(o);
                res
            }
            Step::Reduce(p) => {
                let mut o = self.take_f32(arena, p.out)?;
                let res = (|| {
                    let data = self.f32_src(p.data, args, arena)?;
                    let init = self.f32_src(p.init, args, arena)?[0];
                    kernels::reduce(
                        tier,
                        p.algo,
                        &data[..p.map.len()],
                        init,
                        &p.map,
                        &p.region,
                        &mut o[..p.out_elems],
                    );
                    Ok(())
                })();
                arena.bufs[p.out as usize] = ArenaBuf::F32(o);
                res
            }
            Step::Conv(p) => match (p.conv_algo, p.scratch) {
                // Blocked-direct: the fused kernel gathers patch tiles
                // straight from the lhs and writes folds through `place`
                // — no scratch, no materialization.  Same patch K order
                // under the pinned lanes contract, so bits match the
                // im2col arm exactly.
                (cost::ConvAlgo::Blocked, _) => {
                    let mut o = self.take_f32(arena, p.out)?;
                    let res = (|| {
                        let l = self.f32_src(p.lhs, args, arena)?;
                        let r = self.f32_src(p.rhs, args, arena)?;
                        for g in &p.groups {
                            kernels::conv_blocked(
                                tier,
                                l,
                                r,
                                &g.patch_map,
                                &g.w_map,
                                &g.place,
                                p.m,
                                p.k,
                                p.ng,
                                &mut o[..],
                            );
                        }
                        Ok(())
                    })();
                    arena.bufs[p.out as usize] = ArenaBuf::F32(o);
                    res
                }
                // im2col per feature group: pad builds the [m, k] patch
                // matrix (u32::MAX map entries fill the halo with zeros),
                // gather builds the [k, ng] group weight matrix, then the
                // cost-model-picked dot runs under the pinned lanes
                // contract and scatter_part places the [m, ng] group
                // result into the output layout.
                (cost::ConvAlgo::Im2col, Some(scratch)) => {
                    let mut patch = self.take_f32(arena, scratch[0])?;
                    let mut w = self.take_f32(arena, scratch[1])?;
                    let mut acc = self.take_f32(arena, scratch[2])?;
                    let mut o = self.take_f32(arena, p.out)?;
                    let res = (|| {
                        let l = self.f32_src(p.lhs, args, arena)?;
                        let r = self.f32_src(p.rhs, args, arena)?;
                        for g in &p.groups {
                            kernels::pad(l, 0.0, &g.patch_map, &mut patch[..p.m * p.k]);
                            kernels::gather(r, &g.w_map, &mut w[..p.k * p.ng]);
                            kernels::dot(
                                tier,
                                p.algo,
                                &patch[..p.m * p.k],
                                &w[..p.k * p.ng],
                                &p.l_base,
                                &p.r_base,
                                1,
                                p.ng,
                                p.k,
                                &mut acc[..p.m * p.ng],
                            );
                            kernels::scatter_part(&acc[..p.m * p.ng], &g.place, &mut o[..]);
                        }
                        Ok(())
                    })();
                    arena.bufs[scratch[0] as usize] = ArenaBuf::F32(patch);
                    arena.bufs[scratch[1] as usize] = ArenaBuf::F32(w);
                    arena.bufs[scratch[2] as usize] = ArenaBuf::F32(acc);
                    arena.bufs[p.out as usize] = ArenaBuf::F32(o);
                    res
                }
                (cost::ConvAlgo::Im2col, None) => {
                    Err(err("im2col conv plan without reserved scratch".into()))
                }
            },
            Step::DynSlice {
                dtype,
                src,
                starts,
                src_dims,
                sizes,
                out,
            } => {
                let offs = self.start_offsets(starts, src_dims, sizes, args, arena)?;
                let n: usize = sizes.iter().product();
                match dtype {
                    DType::F32 => {
                        let mut o = self.take_f32(arena, *out)?;
                        let res = self.f32_src(*src, args, arena).map(|s| {
                            kernels::dyn_slice(s, src_dims, &offs, sizes, &mut o[..n]);
                        });
                        arena.bufs[*out as usize] = ArenaBuf::F32(o);
                        res
                    }
                    DType::S32 => {
                        let mut o = self.take_i32(arena, *out)?;
                        let res = self.i32_src(*src, args, arena).map(|s| {
                            kernels::dyn_slice(s, src_dims, &offs, sizes, &mut o[..n]);
                        });
                        arena.bufs[*out as usize] = ArenaBuf::I32(o);
                        res
                    }
                    DType::Pred => {
                        let mut o = self.take_pred(arena, *out)?;
                        let res = self.pred_src(*src, args, arena).map(|s| {
                            kernels::dyn_slice(s, src_dims, &offs, sizes, &mut o[..n]);
                        });
                        arena.bufs[*out as usize] = ArenaBuf::Pred(o);
                        res
                    }
                }
            }
            Step::DynUpdate {
                dtype,
                src,
                upd,
                starts,
                src_dims,
                upd_dims,
                out,
            } => {
                let offs = self.start_offsets(starts, src_dims, upd_dims, args, arena)?;
                let n: usize = src_dims.iter().product();
                let un: usize = upd_dims.iter().product();
                match dtype {
                    DType::F32 => {
                        let mut o = self.take_f32(arena, *out)?;
                        let res = (|| {
                            let s = self.f32_src(*src, args, arena)?;
                            let u = self.f32_src(*upd, args, arena)?;
                            kernels::dyn_update(
                                &s[..n],
                                &u[..un],
                                src_dims,
                                &offs,
                                upd_dims,
                                &mut o[..n],
                            );
                            Ok(())
                        })();
                        arena.bufs[*out as usize] = ArenaBuf::F32(o);
                        res
                    }
                    DType::S32 => {
                        let mut o = self.take_i32(arena, *out)?;
                        let res = (|| {
                            let s = self.i32_src(*src, args, arena)?;
                            let u = self.i32_src(*upd, args, arena)?;
                            kernels::dyn_update(
                                &s[..n],
                                &u[..un],
                                src_dims,
                                &offs,
                                upd_dims,
                                &mut o[..n],
                            );
                            Ok(())
                        })();
                        arena.bufs[*out as usize] = ArenaBuf::I32(o);
                        res
                    }
                    DType::Pred => {
                        let mut o = self.take_pred(arena, *out)?;
                        let res = (|| {
                            let s = self.pred_src(*src, args, arena)?;
                            let u = self.pred_src(*upd, args, arena)?;
                            kernels::dyn_update(
                                &s[..n],
                                &u[..un],
                                src_dims,
                                &offs,
                                upd_dims,
                                &mut o[..n],
                            );
                            Ok(())
                        })();
                        arena.bufs[*out as usize] = ArenaBuf::Pred(o);
                        res
                    }
                }
            }
            Step::Call {
                callee,
                args: cargs,
                outs,
            } => {
                let results = {
                    let mut views = Vec::with_capacity(cargs.len());
                    for (&r, spec) in cargs.iter().zip(&callee.params) {
                        views.push(self.view_of(r, spec, args, arena)?);
                    }
                    callee.execute_raw(&views, tier)?
                };
                self.store_results(results, outs, arena)
            }
            Step::While {
                cond,
                body,
                init,
                outs,
            } => {
                // Loop-carried state lives in owned buffers so the parent
                // arena is only borrowed immutably while a sub-program
                // runs; the body's results become the next state without
                // touching parent slots until the loop exits (zero-trip
                // then stores the initial state unchanged).
                let mut state: Vec<OwnBuf> = Vec::with_capacity(init.len());
                for (&r, spec) in init.iter().zip(&body.params) {
                    state.push(self.own_of(r, spec, args, arena)?);
                }
                loop {
                    let go = {
                        let views: Vec<ArgView> = state.iter().map(OwnBuf::view).collect();
                        match cond.execute_raw(&views, tier)?.first() {
                            Some(OwnBuf::Pred(v)) if !v.is_empty() => v[0],
                            _ => {
                                return Err(internal(
                                    "while condition must produce a scalar pred",
                                ))
                            }
                        }
                    };
                    if !go {
                        break;
                    }
                    let next = {
                        let views: Vec<ArgView> = state.iter().map(OwnBuf::view).collect();
                        body.execute_raw(&views, tier)?
                    };
                    if next.len() != state.len() {
                        return Err(internal("while body arity mismatch"));
                    }
                    state = next;
                }
                self.store_results(state, outs, arena)
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn run_convert(
        &self,
        from: DType,
        to: DType,
        a: Ref,
        out: u32,
        n: usize,
        args: &[ArgView],
        arena: &mut Arena,
    ) -> Result<()> {
        match to {
            DType::F32 => {
                let mut o = self.take_f32(arena, out)?;
                let res = (|| {
                    match from {
                        DType::F32 => {
                            let v = self.f32_src(a, args, arena)?;
                            o[..n].copy_from_slice(&v[..n]);
                        }
                        DType::S32 => {
                            let v = self.i32_src(a, args, arena)?;
                            for (d, &x) in o[..n].iter_mut().zip(v) {
                                *d = x as f32;
                            }
                        }
                        DType::Pred => {
                            let v = self.pred_src(a, args, arena)?;
                            for (d, &x) in o[..n].iter_mut().zip(v) {
                                *d = if x { 1.0 } else { 0.0 };
                            }
                        }
                    }
                    Ok(())
                })();
                arena.bufs[out as usize] = ArenaBuf::F32(o);
                res
            }
            DType::S32 => {
                let mut o = self.take_i32(arena, out)?;
                let res = (|| {
                    match from {
                        DType::S32 => {
                            let v = self.i32_src(a, args, arena)?;
                            o[..n].copy_from_slice(&v[..n]);
                        }
                        // XLA convert f32->s32 rounds toward zero.
                        DType::F32 => {
                            let v = self.f32_src(a, args, arena)?;
                            for (d, &x) in o[..n].iter_mut().zip(v) {
                                *d = x as i32;
                            }
                        }
                        DType::Pred => {
                            let v = self.pred_src(a, args, arena)?;
                            for (d, &x) in o[..n].iter_mut().zip(v) {
                                *d = i32::from(x);
                            }
                        }
                    }
                    Ok(())
                })();
                arena.bufs[out as usize] = ArenaBuf::I32(o);
                res
            }
            DType::Pred => {
                let mut o = self.take_pred(arena, out)?;
                let res = (|| {
                    match from {
                        DType::Pred => {
                            let v = self.pred_src(a, args, arena)?;
                            o[..n].copy_from_slice(&v[..n]);
                        }
                        DType::F32 => {
                            let v = self.f32_src(a, args, arena)?;
                            for (d, &x) in o[..n].iter_mut().zip(v) {
                                *d = x != 0.0;
                            }
                        }
                        DType::S32 => {
                            let v = self.i32_src(a, args, arena)?;
                            for (d, &x) in o[..n].iter_mut().zip(v) {
                                *d = x != 0;
                            }
                        }
                    }
                    Ok(())
                })();
                arena.bufs[out as usize] = ArenaBuf::Pred(o);
                res
            }
        }
    }

    fn collect_raw(&self, args: &[ArgView], arena: &Arena) -> Result<Vec<OwnBuf>> {
        let mut out = Vec::with_capacity(self.outputs.len());
        for o in &self.outputs {
            let n: i64 = o.dims.iter().product();
            let n = n as usize;
            out.push(match o.dtype {
                DType::F32 => OwnBuf::F32(self.f32_src(o.r, args, arena)?[..n].to_vec()),
                DType::S32 => OwnBuf::I32(self.i32_src(o.r, args, arena)?[..n].to_vec()),
                DType::Pred => OwnBuf::Pred(self.pred_src(o.r, args, arena)?[..n].to_vec()),
            });
        }
        Ok(out)
    }

    fn collect_outputs(&self, args: &[ArgView], arena: &Arena) -> Result<Literal> {
        let mut parts = Vec::with_capacity(self.outputs.len());
        for o in &self.outputs {
            let n: i64 = o.dims.iter().product();
            let n = n as usize;
            let data = match o.dtype {
                DType::F32 => Data::F32(self.f32_src(o.r, args, arena)?[..n].to_vec()),
                DType::S32 => Data::I32(self.i32_src(o.r, args, arena)?[..n].to_vec()),
                DType::Pred => Data::I32(
                    self.pred_src(o.r, args, arena)?[..n]
                        .iter()
                        .map(|&b| i32::from(b))
                        .collect(),
                ),
            };
            parts.push(Literal::from_data(data, o.dims.clone()));
        }
        if self.tuple_root {
            Ok(Literal::tuple(parts))
        } else {
            Ok(parts.into_iter().next().expect("at least one output"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_slot_buffers_are_32_byte_aligned() {
        assert_eq!(std::mem::size_of::<Lane8>(), 32);
        assert_eq!(std::mem::align_of::<Lane8>(), 32);
        for len in [0usize, 1, 7, 8, 9, 64, 1000] {
            let mut b = AlignedF32::zeroed(len);
            assert_eq!(b.len(), len);
            assert_eq!(b.as_ptr() as usize % 32, 0, "len {len}");
            assert!(b.iter().all(|&x| x == 0.0));
            b.grow(len + 13);
            assert_eq!(b.len(), len + 13);
            assert_eq!(b.as_ptr() as usize % 32, 0, "grown from {len}");
            // Newly exposed elements are zeroed — growth is deterministic.
            assert!(b.iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn arena_f32_slots_honor_alignment() {
        let arena = Arena::for_slots(&[
            SlotSpec {
                dtype: DType::F32,
                max_elems: 5,
            },
            SlotSpec {
                dtype: DType::F32,
                max_elems: 64,
            },
            SlotSpec {
                dtype: DType::S32,
                max_elems: 3,
            },
        ]);
        for buf in &arena.bufs {
            if let ArenaBuf::F32(v) = buf {
                assert_eq!(v.as_ptr() as usize % 32, 0);
            }
        }
    }
}
