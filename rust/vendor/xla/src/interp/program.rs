//! Lowering: a parsed [`Module`] becomes a flat SSA register [`Program`].
//!
//! Everything shape-dependent is resolved **here, at compile time**:
//!
//! * operand names -> dense value-slot indices ([`Ref`]);
//! * broadcast/transpose/slice/pad/concatenate -> precomputed gather maps
//!   (`out_flat -> in_flat`), so execution is a single tight loop with no
//!   per-element coordinate decoding;
//! * `dot` -> a [`DotPlan`] with precomputed row/column base offsets and
//!   contraction strides;
//! * `reduce` -> a [`ReducePlan`] with a flat `in -> out` index map and a
//!   compiled region ([`RegionFn`]): one-op regions become direct
//!   accumulator kernels, multi-op regions a scalar register program —
//!   never per-element tree re-evaluation;
//! * adjacent f32 elementwise instructions whose intermediates have
//!   exactly one consumer fuse into a [`FusedLoop`] (single pass, block
//!   scratch registers, no materialized intermediates);
//! * a last-use liveness analysis assigns every materialized value a
//!   reusable arena slot ([`SlotSpec`]), sized to its maximum occupant, so
//!   steady-state execution allocates nothing.
//!
//! Slot-reuse safety invariant: a step's output slot is allocated
//! **before** its dying operands are freed, so an output buffer never
//! aliases a live (or same-step) input.  `slot_reuse_is_alias_free` in the
//! tests walks every compiled program and checks the invariant
//! exhaustively.

use std::collections::HashMap;
use std::sync::atomic::AtomicU64;
use std::sync::{Arc, Mutex};

use super::cost;
use super::exec::Arena;
use super::parse::{
    coords_of, declared_dense, elements, err, strides, Computation, ConstPayload, DType, Module,
    Shape, ShapeSpec,
};
use crate::Result;

/// Where a value lives at execution time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Ref {
    /// An arena slot (materialized intermediate).
    Slot(u32),
    /// An entry parameter, borrowed straight from the caller's `Literal`.
    Param(u32),
    /// An entry in the compile-time constant pool.
    Const(u32),
}

/// f32 elementwise op kinds (fused loops + scalar region programs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum EwOp {
    Add,
    Sub,
    Mul,
    Div,
    Max,
    Min,
    Pow,
    Rem,
    Abs,
    Neg,
    Exp,
    ExpM1,
    Log,
    Log1p,
    Logistic,
    Tanh,
    Sqrt,
    Rsqrt,
    Sign,
    Floor,
    Ceil,
    Cos,
    Sin,
    Copy,
}

impl EwOp {
    /// `(op, is_binary)` for an f32-elementwise HLO opcode.
    fn from_name(op: &str) -> Option<(EwOp, bool)> {
        Some(match op {
            "add" => (EwOp::Add, true),
            "subtract" => (EwOp::Sub, true),
            "multiply" => (EwOp::Mul, true),
            "divide" => (EwOp::Div, true),
            "maximum" => (EwOp::Max, true),
            "minimum" => (EwOp::Min, true),
            "power" => (EwOp::Pow, true),
            "remainder" => (EwOp::Rem, true),
            "abs" => (EwOp::Abs, false),
            "negate" => (EwOp::Neg, false),
            "exponential" => (EwOp::Exp, false),
            "exponential-minus-one" => (EwOp::ExpM1, false),
            "log" => (EwOp::Log, false),
            "log-plus-one" => (EwOp::Log1p, false),
            "logistic" => (EwOp::Logistic, false),
            "tanh" => (EwOp::Tanh, false),
            "sqrt" => (EwOp::Sqrt, false),
            "rsqrt" => (EwOp::Rsqrt, false),
            "sign" => (EwOp::Sign, false),
            "floor" => (EwOp::Floor, false),
            "ceil" => (EwOp::Ceil, false),
            "cosine" => (EwOp::Cos, false),
            "sine" => (EwOp::Sin, false),
            "copy" => (EwOp::Copy, false),
            _ => return None,
        })
    }
}

/// i32 elementwise op kinds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum IntOp {
    Add,
    Sub,
    Mul,
    Max,
    Min,
    And,
    Or,
    Xor,
    Abs,
    Neg,
    Sign,
    Copy,
}

/// pred elementwise op kinds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum PredOp {
    And,
    Or,
    Xor,
    Not,
    Copy,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum CmpDir {
    Eq,
    Ne,
    Lt,
    Gt,
    Le,
    Ge,
}

/// A lane source inside a fused loop: an external input block or the
/// result register of an earlier op in the same group.
#[derive(Clone, Copy, Debug)]
pub(crate) enum Lane {
    In(u8),
    Reg(u8),
}

/// One op of a fused loop; its result register index is its position in
/// [`FusedLoop::ops`].
#[derive(Clone, Copy, Debug)]
pub(crate) struct LaneOp {
    pub(crate) op: EwOp,
    pub(crate) a: Lane,
    pub(crate) b: Option<Lane>,
}

/// A fused single-pass f32 elementwise loop (1..=MAX_FUSED_OPS ops).
#[derive(Clone, Debug)]
pub(crate) struct FusedLoop {
    pub(crate) n: usize,
    pub(crate) inputs: Vec<Ref>,
    pub(crate) ops: Vec<LaneOp>,
    pub(crate) out: u32,
}

pub(crate) const MAX_FUSED_OPS: usize = 12;
pub(crate) const MAX_FUSED_INPUTS: usize = 12;
/// Cap on compiled reduce-region ops (sizes the scalar register file).
pub(crate) const MAX_REGION_OPS: usize = 32;

/// Precompiled `dot`: collapsed (M, K) x (K, N) with base-offset tables.
///
/// Batched dots (`lhs_batch_dims`/`rhs_batch_dims`, the shape jax vmap
/// gradients emit) lower to `b` consecutive per-slice base tables over
/// the same geometry; execution runs the kernel once per slice into
/// `out[slice * m * n ..]`, matching the XLA output layout (batch dims
/// first, then lhs free, then rhs free).
#[derive(Clone, Debug)]
pub(crate) struct DotPlan {
    pub(crate) lhs: Ref,
    pub(crate) rhs: Ref,
    pub(crate) out: u32,
    /// Batch slices; 1 for an unbatched dot.
    pub(crate) b: usize,
    pub(crate) m: usize,
    pub(crate) n: usize,
    pub(crate) k: usize,
    /// `b * m` row bases (absolute, batch offset folded in).
    pub(crate) l_base: Vec<u32>,
    /// `b * n` column bases (absolute, batch offset folded in).
    pub(crate) r_base: Vec<u32>,
    pub(crate) l_kstride: usize,
    pub(crate) r_kstride: usize,
    /// Execution strategy picked by the compile-time cost model
    /// ([`super::cost::select_dot_algo`]).  Strategy only: every variant
    /// follows the pinned lanes contract, so this never affects bits.
    pub(crate) algo: cost::DotAlgo,
}

/// A scalar operand of a compiled reduce region.
#[derive(Clone, Copy, Debug)]
pub(crate) enum ScalarSrc {
    /// Region parameter 0: the running accumulator.
    Acc,
    /// Region parameter 1: the incoming element.
    X,
    Const(u8),
    Reg(u8),
}

#[derive(Clone, Copy, Debug)]
pub(crate) struct ScalarOp {
    pub(crate) op: EwOp,
    pub(crate) a: ScalarSrc,
    pub(crate) b: Option<ScalarSrc>,
}

/// A multi-op reduce region compiled to scalar register form: applied per
/// element with zero allocation (satellite: no per-element region
/// re-evaluation, ever).
#[derive(Clone, Debug)]
pub(crate) struct ScalarProgram {
    pub(crate) ops: Vec<ScalarOp>,
    pub(crate) consts: Vec<f32>,
    pub(crate) result: ScalarSrc,
}

#[derive(Clone, Debug)]
pub(crate) enum RegionFn {
    Add,
    Mul,
    Max,
    Min,
    Program(ScalarProgram),
}

/// Precompiled `reduce` over f32 data.
#[derive(Clone, Debug)]
pub(crate) struct ReducePlan {
    pub(crate) data: Ref,
    pub(crate) init: Ref,
    pub(crate) out: u32,
    pub(crate) out_elems: usize,
    /// `map[in_flat] = out_flat`; flat-ascending iteration order for the
    /// [`super::cost::ReduceAlgo::Flat`] strategy (matching the reference
    /// evaluator bit for bit).
    pub(crate) map: Vec<u32>,
    pub(crate) region: RegionFn,
    /// Execution strategy picked by the compile-time cost model: the
    /// grouped-contiguous-Add layout runs the pinned lanes contract,
    /// everything else the flat walk.
    pub(crate) algo: cost::ReduceAlgo,
}

/// One feature group of a precompiled convolution.
#[derive(Clone, Debug)]
pub(crate) struct ConvGroup {
    /// Patch gather `patch[r*k + c] <- lhs[map]` (`u32::MAX` -> 0.0 where
    /// the window hangs into padding).
    pub(crate) patch_map: Vec<u32>,
    /// Weight gather `w[c*ng + j] <- rhs[map]` (matches the patch column
    /// order).
    pub(crate) w_map: Vec<u32>,
    /// Output scatter `out[place[r*ng + j]] = acc[r*ng + j]`.
    pub(crate) place: Vec<u32>,
}

/// Precompiled `convolution`, one [`ConvGroup`] per feature group.  The
/// conv-aware cost model picks one of two strategies per conv:
///
/// * **blocked-direct** ([`cost::ConvAlgo::Blocked`]): the fused kernel
///   gathers patch tiles straight from the lhs through `patch_map` into
///   8-lane registers and writes folds through `place` — no scratch at
///   all;
/// * **im2col** ([`cost::ConvAlgo::Im2col`]): three shared scratch slots
///   hold the patch matrix `[m, k]`, the gathered weights `[k, ng]` and
///   the dot result `[m, ng]`, replaying the cost-model-picked dot plan.
///
/// Both run the pinned 8-lane accumulation contract over the same patch
/// K order, so the choice (and both tiers) stay bit-identical by
/// construction.
#[derive(Clone, Debug)]
pub(crate) struct ConvPlan {
    pub(crate) lhs: Ref,
    pub(crate) rhs: Ref,
    pub(crate) out: u32,
    pub(crate) m: usize,
    pub(crate) k: usize,
    /// Output features per group (the `n` of the per-group dot).
    pub(crate) ng: usize,
    pub(crate) groups: Vec<ConvGroup>,
    /// `[patch, weights, acc]` scratch slots (shared by every im2col conv
    /// in the program; reserved outside the free lists).  `None` for
    /// blocked plans — the fused kernel materializes nothing.
    pub(crate) scratch: Option<[u32; 3]>,
    /// Row bases `0, k, 2k, ...` of the row-major patch matrix.
    pub(crate) l_base: Vec<u32>,
    /// Column bases `0..ng` of the row-major weight matrix.
    pub(crate) r_base: Vec<u32>,
    /// Dot strategy of the im2col arm (strategy only — the lanes contract
    /// means it never affects bits).
    pub(crate) algo: cost::DotAlgo,
    /// Conv strategy from the compile-time cost model (or the
    /// `DIVEBATCH_CONV_ALGO` override); strategy only, never bits.
    pub(crate) conv_algo: cost::ConvAlgo,
}

/// One execution step of the register program.
#[derive(Clone, Debug)]
pub(crate) enum Step {
    Fused(FusedLoop),
    IntEw {
        op: IntOp,
        a: Ref,
        b: Option<Ref>,
        out: u32,
        n: usize,
    },
    PredEw {
        op: PredOp,
        a: Ref,
        b: Option<Ref>,
        out: u32,
        n: usize,
    },
    Compare {
        dir: CmpDir,
        dtype: DType,
        a: Ref,
        b: Ref,
        out: u32,
        n: usize,
    },
    Select {
        dtype: DType,
        p: Ref,
        t: Ref,
        f: Ref,
        out: u32,
        n: usize,
        scalar_pred: bool,
    },
    Convert {
        from: DType,
        to: DType,
        a: Ref,
        out: u32,
        n: usize,
    },
    /// broadcast / transpose / slice: `out[i] = src[map[i]]`.
    Gather {
        dtype: DType,
        src: Ref,
        map: Vec<u32>,
        out: u32,
    },
    /// pad: `out[i] = map[i] == u32::MAX ? fill : src[map[i]]`.
    Pad {
        dtype: DType,
        src: Ref,
        fill: Ref,
        map: Vec<u32>,
        out: u32,
    },
    /// concatenate: per part, `out[place[j]] = part[j]`.
    Concat {
        dtype: DType,
        parts: Vec<(Ref, Vec<u32>)>,
        out: u32,
        n: usize,
    },
    Dot(DotPlan),
    Reduce(ReducePlan),
    Conv(ConvPlan),
    /// dynamic-slice: runtime scalar s32 starts, clamped per HLO to
    /// `0 <= start <= dim - size`.
    DynSlice {
        dtype: DType,
        src: Ref,
        starts: Vec<Ref>,
        src_dims: Vec<usize>,
        sizes: Vec<usize>,
        out: u32,
    },
    /// dynamic-update-slice: copy the operand, overwrite the clamped
    /// window with the update.
    DynUpdate {
        dtype: DType,
        src: Ref,
        upd: Ref,
        starts: Vec<Ref>,
        src_dims: Vec<usize>,
        upd_dims: Vec<usize>,
        out: u32,
    },
    /// call: run the compiled callee on borrowed argument views; one
    /// output slot per callee output.
    Call {
        callee: Arc<Program>,
        args: Vec<Ref>,
        outs: Vec<u32>,
    },
    /// while: compiled condition/body sub-programs over slot-stable
    /// loop-carried state (one arena slot per state tuple element).
    While {
        cond: Arc<Program>,
        body: Arc<Program>,
        init: Vec<Ref>,
        outs: Vec<u32>,
    },
}

/// An arena slot: fixed dtype, sized once to its largest occupant.
#[derive(Clone, Debug)]
pub(crate) struct SlotSpec {
    pub(crate) dtype: DType,
    pub(crate) max_elems: usize,
}

/// A declared entry parameter (for argument validation + error messages).
#[derive(Clone, Debug)]
pub(crate) struct ParamSpec {
    pub(crate) name: String,
    pub(crate) dtype: DType,
    pub(crate) dims: Vec<usize>,
}

/// One entry output.
#[derive(Clone, Debug)]
pub(crate) struct OutSpec {
    pub(crate) r: Ref,
    pub(crate) dtype: DType,
    pub(crate) dims: Vec<i64>,
}

/// Constant-pool storage.
#[derive(Clone, Debug)]
pub(crate) enum ConstBuf {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Pred(Vec<bool>),
}

/// The compiled register program for an entry computation.
#[derive(Debug)]
pub(crate) struct Program {
    pub(crate) entry_name: String,
    pub(crate) steps: Vec<Step>,
    pub(crate) slots: Vec<SlotSpec>,
    pub(crate) consts: Vec<ConstBuf>,
    pub(crate) params: Vec<ParamSpec>,
    pub(crate) outputs: Vec<OutSpec>,
    pub(crate) tuple_root: bool,
    /// Reusable execution arenas (popped per call, pushed back after).
    pub(crate) pool: Mutex<Vec<Arena>>,
    /// Allocs-proxy counters: arenas created, buffers (re)grown.
    pub(crate) arenas_created: AtomicU64,
    pub(crate) buffers_grown: AtomicU64,
}

// ------------------------------------------------------------ compilation

/// How each SSA value is realized.
#[derive(Clone, Debug)]
enum Kind {
    Param(u32),
    Const(u32),
    /// A tuple-shaped sub-computation parameter, flattened into dense
    /// params `start .. start + arity` (addressable via get-tuple-element
    /// only).
    ParamTuple { start: u32, arity: usize },
    /// Materialized into an arena slot (assigned during emission) unless
    /// fused away.
    Inst,
    /// Same flat data as another SSA value (reshape, get-tuple-element).
    Alias(usize),
    /// A tuple of SSA values (root, or feeding get-tuple-element only).
    Tuple(Vec<usize>),
    /// Element `idx` of a multi-output instruction (`while`, tuple-shaped
    /// `call`): liveness and slot assignment treat it like [`Kind::Inst`],
    /// but the owner's step writes it — the canonical get-tuple-element
    /// emits nothing itself.
    MultiPart { owner: usize, idx: usize },
}

/// Sub-computation nesting cap (while/call bodies); generous for real
/// modules, small enough to bound hostile self-referential input.
const MAX_SUB_DEPTH: usize = 32;

struct Lowering<'m> {
    module: &'m Module,
    comp: &'m Computation,
    /// Entry computations face host-argument restrictions (no tuple or
    /// pred parameters); sub-programs flatten tuple params instead.
    is_entry: bool,
    depth: usize,
    kinds: Vec<Kind>,
    dims: Vec<Vec<usize>>,
    dtypes: Vec<DType>,
    consts: Vec<ConstBuf>,
    params: Vec<ParamSpec>,
    /// Flat parameter offset per parameter number (tuple params occupy
    /// one flat slot per element).
    param_offset: Vec<usize>,
    /// Tuple element shapes of multi-output instructions, by index.
    multi_shapes: HashMap<usize, Vec<Shape>>,
    /// Canonical get-tuple-element per (owner, element index).
    multi_canon: HashMap<(usize, usize), usize>,
    inlined: Vec<bool>,
    /// Single consumer index (valid when consumer_count == 1).
    consumer: Vec<usize>,
    consumer_count: Vec<usize>,
    is_output: Vec<bool>,
}

impl Program {
    pub(crate) fn compile(module: &Module) -> Result<Program> {
        Self::compile_computation(module, module.entry_computation(), true, 0)
    }

    fn compile_computation(
        module: &Module,
        comp: &Computation,
        is_entry: bool,
        depth: usize,
    ) -> Result<Program> {
        if depth > MAX_SUB_DEPTH {
            return Err(err(format!(
                "computation {:?} exceeds nesting depth {MAX_SUB_DEPTH} (while/call cycle?)",
                comp.name
            )));
        }
        let mut param_offset = vec![0usize; comp.params.len()];
        let mut flat_params = 0usize;
        for (p, &pi) in comp.params.iter().enumerate() {
            param_offset[p] = flat_params;
            flat_params += match &comp.instrs[pi].shape {
                ShapeSpec::Tuple(parts) => parts.len(),
                ShapeSpec::Dense(_) => 1,
            };
        }
        let mut lw = Lowering {
            module,
            comp,
            is_entry,
            depth,
            kinds: Vec::with_capacity(comp.instrs.len()),
            dims: Vec::with_capacity(comp.instrs.len()),
            dtypes: Vec::with_capacity(comp.instrs.len()),
            consts: Vec::new(),
            params: vec![
                ParamSpec {
                    name: String::new(),
                    dtype: DType::F32,
                    dims: Vec::new(),
                };
                flat_params
            ],
            param_offset,
            multi_shapes: HashMap::new(),
            multi_canon: HashMap::new(),
            inlined: vec![false; comp.instrs.len()],
            consumer: vec![usize::MAX; comp.instrs.len()],
            consumer_count: vec![0; comp.instrs.len()],
            is_output: vec![false; comp.instrs.len()],
        };
        lw.classify()?;
        let outputs_ssa = lw.root_outputs()?;
        lw.count_consumers(&outputs_ssa)?;
        lw.mark_fusion();
        lw.emit(outputs_ssa)
    }
}

impl<'m> Lowering<'m> {
    /// Resolve alias chains to the underlying SSA value.
    fn resolve(&self, mut i: usize) -> usize {
        while let Kind::Alias(t) = self.kinds[i] {
            i = t;
        }
        i
    }

    /// Pass A: classify every instruction; fold constants/iota into the
    /// pool; resolve reshape/get-tuple-element to aliases; record shapes.
    fn classify(&mut self) -> Result<()> {
        for i in 0..self.comp.instrs.len() {
            let ins = &self.comp.instrs[i];
            // HLO text lists operands before their uses; the whole
            // lowering (alias resolution, liveness, slot refs) relies on
            // that, so enforce it up front.
            for &o in &ins.operands {
                if o >= i {
                    return Err(err(format!(
                        "{}: operand used before definition",
                        ins.name
                    )));
                }
            }
            let (dt, dm): (DType, Vec<usize>) = match &ins.shape {
                ShapeSpec::Dense(s) => (s.dtype, s.dims.clone()),
                // Tuples have no single dtype; placeholder never read.
                ShapeSpec::Tuple(_) => (DType::F32, Vec::new()),
            };
            if elements(&dm) >= u32::MAX as usize {
                return Err(err(format!(
                    "{}: tensor too large for the interp backend",
                    ins.name
                )));
            }
            let kind = match ins.op.as_str() {
                "parameter" => {
                    let p = ins.param.expect("parameter number");
                    let off = self.param_offset[p];
                    match &ins.shape {
                        ShapeSpec::Dense(s) => {
                            if self.is_entry && s.dtype == DType::Pred {
                                return Err(err(format!(
                                    "entry {:?}: parameter {:?} is pred-typed; pred entry \
                                     parameters are not supported by the interp backend \
                                     (pass s32/f32 and compare inside the computation)",
                                    self.comp.name, ins.name
                                )));
                            }
                            self.params[off] = ParamSpec {
                                name: ins.name.clone(),
                                dtype: s.dtype,
                                dims: s.dims.clone(),
                            };
                            Kind::Param(off as u32)
                        }
                        ShapeSpec::Tuple(parts) => {
                            if self.is_entry {
                                return Err(err(format!(
                                    "{}: tuple parameters are not supported",
                                    ins.name
                                )));
                            }
                            for (kx, s) in parts.iter().enumerate() {
                                self.params[off + kx] = ParamSpec {
                                    name: format!("{}.{kx}", ins.name),
                                    dtype: s.dtype,
                                    dims: s.dims.clone(),
                                };
                            }
                            Kind::ParamTuple {
                                start: off as u32,
                                arity: parts.len(),
                            }
                        }
                    }
                }
                "constant" => {
                    let c = ins.literal.as_ref().expect("parsed constant");
                    let buf = match &c.payload {
                        ConstPayload::F32(v) => ConstBuf::F32(v.clone()),
                        ConstPayload::I32(v) => ConstBuf::I32(v.clone()),
                        ConstPayload::Pred(v) => ConstBuf::Pred(v.clone()),
                    };
                    self.consts.push(buf);
                    Kind::Const((self.consts.len() - 1) as u32)
                }
                "iota" => {
                    let want = declared_dense(ins)?;
                    let dim = ins.attrs.iota_dimension.unwrap_or(0);
                    if dim >= want.dims.len().max(1) {
                        return Err(err(format!(
                            "iota dimension {dim} out of range for {want}"
                        )));
                    }
                    let st = strides(&want.dims);
                    let n = want.elements();
                    let vals: Vec<usize> = (0..n)
                        .map(|flat| {
                            coords_of(flat, &want.dims, &st)
                                .get(dim)
                                .copied()
                                .unwrap_or(0)
                        })
                        .collect();
                    let buf = match want.dtype {
                        DType::F32 => ConstBuf::F32(vals.iter().map(|&v| v as f32).collect()),
                        DType::S32 => ConstBuf::I32(vals.iter().map(|&v| v as i32).collect()),
                        DType::Pred => ConstBuf::Pred(vals.iter().map(|&v| v != 0).collect()),
                    };
                    self.consts.push(buf);
                    Kind::Const((self.consts.len() - 1) as u32)
                }
                "reshape" => {
                    let &o = ins
                        .operands
                        .first()
                        .ok_or_else(|| err(format!("{}: missing operand 0", ins.name)))?;
                    let want = declared_dense(ins)?;
                    if elements(&self.dims[o]) != want.elements() {
                        return Err(err(format!(
                            "reshape element count mismatch: {} -> {want}",
                            elements(&self.dims[o])
                        )));
                    }
                    Kind::Alias(o)
                }
                "tuple" => Kind::Tuple(ins.operands.clone()),
                "get-tuple-element" => {
                    let &o = ins
                        .operands
                        .first()
                        .ok_or_else(|| err(format!("{}: missing operand 0", ins.name)))?;
                    let idx = ins.attrs.index.ok_or_else(|| {
                        err(format!("{}: get-tuple-element without index", ins.name))
                    })?;
                    match &self.kinds[o] {
                        Kind::Tuple(parts) => {
                            let part = *parts.get(idx).ok_or_else(|| {
                                err(format!("{}: tuple index {idx} out of range", ins.name))
                            })?;
                            Kind::Alias(part)
                        }
                        Kind::ParamTuple { start, arity } => {
                            if idx >= *arity {
                                return Err(err(format!(
                                    "{}: tuple index {idx} out of range",
                                    ins.name
                                )));
                            }
                            Kind::Param(*start + idx as u32)
                        }
                        _ if self.multi_shapes.contains_key(&o) => {
                            if idx >= self.multi_shapes[&o].len() {
                                return Err(err(format!(
                                    "{}: tuple index {idx} out of range",
                                    ins.name
                                )));
                            }
                            match self.multi_canon.get(&(o, idx)) {
                                Some(&c) => Kind::Alias(c),
                                None => {
                                    self.multi_canon.insert((o, idx), i);
                                    Kind::MultiPart { owner: o, idx }
                                }
                            }
                        }
                        _ => {
                            return Err(err(format!(
                                "{}: get-tuple-element of non-tuple",
                                ins.name
                            )));
                        }
                    }
                }
                "while" | "call" if matches!(ins.shape, ShapeSpec::Tuple(_)) => {
                    let ShapeSpec::Tuple(parts) = &ins.shape else {
                        unreachable!("guarded by the match arm");
                    };
                    self.multi_shapes.insert(i, parts.clone());
                    Kind::Inst
                }
                _ => Kind::Inst,
            };
            self.kinds.push(kind);
            self.dims.push(dm);
            self.dtypes.push(dt);
        }
        Ok(())
    }

    /// The entry's output SSA list — RAW (pre-alias-resolution) indices,
    /// so each output keeps its declared shape (a reshape feeding the
    /// root must surface the reshaped dims, not its source's).
    fn root_outputs(&self) -> Result<Vec<usize>> {
        let root = self.resolve(self.comp.root);
        match &self.kinds[root] {
            Kind::Tuple(parts) => Ok(parts.clone()),
            _ => Ok(vec![self.comp.root]),
        }
    }

    fn root_is_tuple(&self) -> bool {
        matches!(self.kinds[self.resolve(self.comp.root)], Kind::Tuple(_))
    }

    /// Values that occupy an arena slot when materialized: real
    /// instructions and elements of multi-output instructions.
    fn is_slot_value(&self, i: usize) -> bool {
        matches!(self.kinds[i], Kind::Inst | Kind::MultiPart { .. })
    }

    /// A value no dense operand may consume directly: tuples, flattened
    /// tuple parameters, and whole multi-output results.
    fn is_tuple_like(&self, r: usize) -> bool {
        matches!(self.kinds[r], Kind::Tuple(_) | Kind::ParamTuple { .. })
            || self.multi_shapes.contains_key(&r)
    }

    /// The RAW (pre-alias-resolution) SSA values of a while's state tuple.
    fn while_init_parts(&self, i: usize) -> Result<Vec<usize>> {
        let ins = &self.comp.instrs[i];
        if ins.operands.len() != 1 {
            return Err(err(format!(
                "{}: while takes exactly one operand",
                ins.name
            )));
        }
        let t = self.resolve(ins.operands[0]);
        let Kind::Tuple(parts) = &self.kinds[t] else {
            return Err(err(format!(
                "{}: while state must be built by a tuple instruction",
                ins.name
            )));
        };
        Ok(parts.clone())
    }

    /// Pass B: consumer counts on the alias-resolved graph.  Tuples may
    /// only feed get-tuple-element or be the root — except `while`, which
    /// consumes its state tuple whole (credited per element).
    fn count_consumers(&mut self, outputs: &[usize]) -> Result<()> {
        for i in 0..self.comp.instrs.len() {
            let ins = &self.comp.instrs[i];
            if matches!(
                ins.op.as_str(),
                "parameter" | "constant" | "iota" | "reshape" | "tuple" | "get-tuple-element"
            ) {
                continue;
            }
            if ins.op == "while" {
                for p in self.while_init_parts(i)? {
                    let r = self.resolve(p);
                    if self.is_tuple_like(r) {
                        return Err(err(format!(
                            "{}: nested tuples in while state are not supported",
                            ins.name
                        )));
                    }
                    if self.is_slot_value(r) {
                        self.consumer_count[r] += 1;
                        self.consumer[r] = i;
                    }
                }
                continue;
            }
            for &o in &ins.operands {
                let r = self.resolve(o);
                if self.is_tuple_like(r) {
                    return Err(err(format!(
                        "{}: tuple values may only feed get-tuple-element or the root",
                        ins.name
                    )));
                }
                if self.is_slot_value(r) {
                    self.consumer_count[r] += 1;
                    self.consumer[r] = i;
                }
            }
        }
        for &o in outputs {
            let r = self.resolve(o);
            if self.is_tuple_like(r) {
                return Err(err("nested tuple outputs are not supported".into()));
            }
            if self.is_slot_value(r) {
                self.is_output[r] = true;
                self.consumer_count[r] += 1;
            }
        }
        Ok(())
    }

    /// Is instruction `i` an f32 elementwise op the fuser understands?
    fn fusable(&self, i: usize) -> bool {
        if !matches!(self.kinds[i], Kind::Inst) {
            return false;
        }
        let ins = &self.comp.instrs[i];
        if self.dtypes[i] != DType::F32 {
            return false;
        }
        let Some((_, binary)) = EwOp::from_name(&ins.op) else {
            return false;
        };
        // Operand dtypes must be f32 too (HLO guarantees it for these
        // opcodes, but a malformed module should not fuse into nonsense).
        let arity = if binary { 2 } else { 1 };
        ins.operands.len() == arity
            && ins
                .operands
                .iter()
                .all(|&o| self.dtypes[self.resolve(o)] == DType::F32)
    }

    /// Pass C: mark single-consumer f32 elementwise values as fused into
    /// their consumer, then demote members of any group that exceeds the
    /// lane-register / input caps until every group fits.
    fn mark_fusion(&mut self) {
        for i in 0..self.comp.instrs.len() {
            if self.fusable(i)
                && self.consumer_count[i] == 1
                && !self.is_output[i]
                && self.consumer[i] != usize::MAX
                && self.fusable(self.consumer[i])
            {
                self.inlined[i] = true;
            }
        }
        loop {
            let mut changed = false;
            for head in 0..self.comp.instrs.len() {
                if !self.fusable(head) || self.inlined[head] {
                    continue;
                }
                let (max_ops, max_inputs) = cost::fusion_caps(elements(&self.dims[head]));
                debug_assert!(max_ops <= MAX_FUSED_OPS && max_inputs <= MAX_FUSED_INPUTS);
                loop {
                    let (ops, inputs) = self.group_size(head);
                    if ops <= max_ops && inputs <= max_inputs {
                        break;
                    }
                    let demoted = self.demote_one(head);
                    debug_assert!(demoted, "oversized group with nothing to demote");
                    changed = true;
                }
            }
            if !changed {
                break;
            }
        }
    }

    /// (op count, distinct external input count) of the group rooted at
    /// `head`.
    fn group_size(&self, head: usize) -> (usize, usize) {
        let mut ops = 0usize;
        let mut inputs: Vec<usize> = Vec::new();
        self.walk_group(head, &mut ops, &mut inputs);
        (ops, inputs.len())
    }

    /// DFS over the fused group rooted at `i`, counting member ops and
    /// collecting the distinct external (non-inlined) input SSA values.
    fn walk_group(&self, i: usize, ops: &mut usize, inputs: &mut Vec<usize>) {
        *ops += 1;
        for &o in &self.comp.instrs[i].operands {
            let r = self.resolve(o);
            if matches!(self.kinds[r], Kind::Inst) && self.inlined[r] {
                self.walk_group(r, ops, inputs);
            } else if !inputs.contains(&r) {
                inputs.push(r);
            }
        }
    }

    /// Un-inline the first inlined member of `head`'s group (it becomes
    /// its own group head).  Returns false if there was none.
    fn demote_one(&mut self, head: usize) -> bool {
        for &o in &self.comp.instrs[head].operands.clone() {
            let r = self.resolve(o);
            if matches!(self.kinds[r], Kind::Inst) && self.inlined[r] {
                // Prefer demoting a deep subtree first.
                if !self.demote_one(r) {
                    self.inlined[r] = false;
                }
                return true;
            }
        }
        false
    }

    /// Pass D: emit steps in instruction order with last-use-based slot
    /// allocation, then package the [`Program`].
    fn emit(self, outputs_ssa: Vec<usize>) -> Result<Program> {
        let n_instr = self.comp.instrs.len();
        // Emission order: every materialized, non-inlined instruction.
        let emit_list: Vec<usize> = (0..n_instr)
            .filter(|&i| matches!(self.kinds[i], Kind::Inst) && !self.inlined[i])
            .collect();

        // Reads per emitted step: the DISTINCT slot-producing SSA values
        // it consumes (deduplicated — `add(x, x)` reads x once; a
        // duplicate here would free a slot twice and alias it).
        let mut reads: Vec<Vec<usize>> = Vec::with_capacity(emit_list.len());
        for &i in &emit_list {
            let mut r: Vec<usize> = Vec::new();
            if self.fusable(i) {
                let mut ops = 0usize;
                let mut inputs: Vec<usize> = Vec::new();
                self.walk_group(i, &mut ops, &mut inputs);
                for ssa in inputs {
                    if self.is_slot_value(ssa) {
                        r.push(ssa);
                    }
                }
            } else if self.comp.instrs[i].op == "while" {
                // The state tuple is consumed whole: the step reads each
                // element (the tuple itself never materializes).
                for p in self.while_init_parts(i)? {
                    let t = self.resolve(p);
                    if self.is_slot_value(t) && !r.contains(&t) {
                        r.push(t);
                    }
                }
            } else {
                for &o in &self.comp.instrs[i].operands {
                    let t = self.resolve(o);
                    if self.is_slot_value(t) && !r.contains(&t) {
                        r.push(t);
                    }
                }
            }
            reads.push(r);
        }
        let mut last_use = vec![usize::MAX; n_instr];
        for (e, r) in reads.iter().enumerate() {
            for &ssa in r {
                last_use[ssa] = match last_use[ssa] {
                    usize::MAX => e,
                    prev => prev.max(e),
                };
            }
        }

        // Slot allocation state.
        fn dt_ix(d: DType) -> usize {
            match d {
                DType::F32 => 0usize,
                DType::S32 => 1,
                DType::Pred => 2,
            }
        }
        fn alloc_slot(
            slots: &mut Vec<SlotSpec>,
            free: &mut [Vec<u32>],
            dtype: DType,
            n: usize,
        ) -> u32 {
            match free[dt_ix(dtype)].pop() {
                Some(s) => {
                    let spec = &mut slots[s as usize];
                    spec.max_elems = spec.max_elems.max(n);
                    s
                }
                None => {
                    slots.push(SlotSpec {
                        dtype,
                        max_elems: n,
                    });
                    (slots.len() - 1) as u32
                }
            }
        }
        let mut slots: Vec<SlotSpec> = Vec::new();
        let mut free: Vec<Vec<u32>> = vec![Vec::new(); 3]; // by dtype index
        let mut slot_of: Vec<u32> = vec![u32::MAX; n_instr];
        let mut steps: Vec<Step> = Vec::with_capacity(emit_list.len());

        // Shared conv scratch: three f32 slots (patch, weights, dot acc)
        // sized to the largest convolution that actually selects the
        // im2col strategy — blocked-direct convs materialize nothing, so
        // a program whose every conv goes blocked reserves no conv
        // scratch at all.  Reserved up front and never entered into the
        // free lists, so they can't alias any value slot.
        let mut conv_scratch: Option<[u32; 3]> = None;
        {
            let mut any_im2col = false;
            let (mut mk, mut kn, mut mn) = (0usize, 0usize, 0usize);
            for &i in &emit_list {
                if self.comp.instrs[i].op == "convolution" {
                    let g = self.conv_geometry(i)?;
                    if conv_algo_for(&g) == cost::ConvAlgo::Im2col {
                        any_im2col = true;
                        mk = mk.max(g.m * g.k);
                        kn = kn.max(g.k * g.ng);
                        mn = mn.max(g.m * g.ng);
                    }
                }
            }
            if any_im2col {
                let base = slots.len() as u32;
                for elems in [mk, kn, mn] {
                    slots.push(SlotSpec {
                        dtype: DType::F32,
                        max_elems: elems,
                    });
                }
                conv_scratch = Some([base, base + 1, base + 2]);
            }
        }

        for (e, &i) in emit_list.iter().enumerate() {
            let step = if let Some(parts) = self.multi_shapes.get(&i) {
                // Multi-output (`while`, tuple `call`): one slot per
                // state/result element, ALL allocated before any dying
                // operand is freed (the alias-safety invariant extends
                // element-wise).
                let mut outs = Vec::with_capacity(parts.len());
                for (kx, s) in parts.iter().enumerate() {
                    let slot = alloc_slot(&mut slots, &mut free, s.dtype, s.elements());
                    if let Some(&c) = self.multi_canon.get(&(i, kx)) {
                        slot_of[c] = slot;
                    }
                    outs.push(slot);
                }
                self.lower_multi(i, outs, &slot_of)?
            } else {
                let dtype = self.dtypes[i];
                let n = elements(&self.dims[i]);
                // Allocate the output slot FIRST (never alias a dying
                // input).
                let out = alloc_slot(&mut slots, &mut free, dtype, n);
                slot_of[i] = out;
                self.lower_step(i, out, &slot_of, conv_scratch)?
            };
            steps.push(step);
            // Free operands whose last use was this step.
            for &ssa in &reads[e] {
                if last_use[ssa] == e && !self.is_output[ssa] {
                    free[dt_ix(self.dtypes[ssa])].push(slot_of[ssa]);
                }
            }
        }

        let tuple_root = self.root_is_tuple();
        let mut outs = Vec::with_capacity(outputs_ssa.len());
        for &o in &outputs_ssa {
            // Shape from the RAW output operand (reshape dims intact),
            // data from the alias-resolved value.
            outs.push(OutSpec {
                r: self.ssa_ref(self.resolve(o), &slot_of),
                dtype: self.dtypes[o],
                dims: self.dims[o].iter().map(|&d| d as i64).collect(),
            });
        }
        Ok(Program {
            entry_name: self.comp.name.clone(),
            steps,
            slots,
            consts: self.consts,
            params: self.params,
            outputs: outs,
            tuple_root,
            pool: Mutex::new(Vec::new()),
            arenas_created: AtomicU64::new(0),
            buffers_grown: AtomicU64::new(0),
        })
    }

    /// The execution-time [`Ref`] of an (alias-resolved) SSA value.
    fn ssa_ref(&self, ssa: usize, slot_of: &[u32]) -> Ref {
        match &self.kinds[ssa] {
            Kind::Param(p) => Ref::Param(*p),
            Kind::Const(c) => Ref::Const(*c),
            Kind::Inst | Kind::MultiPart { .. } => Ref::Slot(slot_of[ssa]),
            Kind::Alias(_) | Kind::Tuple(_) | Kind::ParamTuple { .. } => {
                unreachable!("resolved before ssa_ref")
            }
        }
    }

    fn oref(&self, i: usize, op_ix: usize, slot_of: &[u32]) -> Result<(Ref, usize, DType)> {
        let ins = &self.comp.instrs[i];
        let &o = ins.operands.get(op_ix).ok_or_else(|| {
            err(format!("{}: missing operand {op_ix}", ins.name))
        })?;
        let t = self.resolve(o);
        // Shape/dtype come from the operand as written (reshape may have
        // changed dims; the flat data is the resolved value's).
        Ok((self.ssa_ref(t, slot_of), elements(&self.dims[o]), self.dtypes[o]))
    }

    fn odims(&self, i: usize, op_ix: usize) -> &[usize] {
        &self.dims[self.comp.instrs[i].operands[op_ix]]
    }

    /// Build the [`Step`] for instruction `i` writing slot `out`.
    fn lower_step(
        &self,
        i: usize,
        out: u32,
        slot_of: &[u32],
        conv_scratch: Option<[u32; 3]>,
    ) -> Result<Step> {
        let ins = &self.comp.instrs[i];
        let n = elements(&self.dims[i]);
        let name = &ins.name;

        if self.fusable(i) {
            return Ok(Step::Fused(self.collect_group(i, out, slot_of)?));
        }

        match ins.op.as_str() {
            "add" | "subtract" | "multiply" | "maximum" | "minimum" | "and" | "or" | "xor"
                if self.dtypes[i] == DType::S32 =>
            {
                let op = match ins.op.as_str() {
                    "add" => IntOp::Add,
                    "subtract" => IntOp::Sub,
                    "multiply" => IntOp::Mul,
                    "maximum" => IntOp::Max,
                    "minimum" => IntOp::Min,
                    "and" => IntOp::And,
                    "or" => IntOp::Or,
                    _ => IntOp::Xor,
                };
                let (a, na, da) = self.oref(i, 0, slot_of)?;
                let (b, nb, db) = self.oref(i, 1, slot_of)?;
                self.check_binary(name, &ins.op, na, da, nb, db, n, DType::S32)?;
                Ok(Step::IntEw {
                    op,
                    a,
                    b: Some(b),
                    out,
                    n,
                })
            }
            "abs" | "negate" | "sign" | "copy" if self.dtypes[i] == DType::S32 => {
                let op = match ins.op.as_str() {
                    "abs" => IntOp::Abs,
                    "negate" => IntOp::Neg,
                    "sign" => IntOp::Sign,
                    _ => IntOp::Copy,
                };
                let (a, na, da) = self.oref(i, 0, slot_of)?;
                self.check_unary(name, &ins.op, na, da, n, DType::S32)?;
                Ok(Step::IntEw {
                    op,
                    a,
                    b: None,
                    out,
                    n,
                })
            }
            "and" | "or" | "xor" if self.dtypes[i] == DType::Pred => {
                let op = match ins.op.as_str() {
                    "and" => PredOp::And,
                    "or" => PredOp::Or,
                    _ => PredOp::Xor,
                };
                let (a, na, da) = self.oref(i, 0, slot_of)?;
                let (b, nb, db) = self.oref(i, 1, slot_of)?;
                self.check_binary(name, &ins.op, na, da, nb, db, n, DType::Pred)?;
                Ok(Step::PredEw {
                    op,
                    a,
                    b: Some(b),
                    out,
                    n,
                })
            }
            "not" | "copy" if self.dtypes[i] == DType::Pred => {
                let op = if ins.op == "not" {
                    PredOp::Not
                } else {
                    PredOp::Copy
                };
                let (a, na, da) = self.oref(i, 0, slot_of)?;
                self.check_unary(name, &ins.op, na, da, n, DType::Pred)?;
                Ok(Step::PredEw {
                    op,
                    a,
                    b: None,
                    out,
                    n,
                })
            }
            "compare" => {
                let dir = match ins.attrs.direction.as_deref() {
                    Some("EQ") => CmpDir::Eq,
                    Some("NE") => CmpDir::Ne,
                    Some("LT") => CmpDir::Lt,
                    Some("GT") => CmpDir::Gt,
                    Some("LE") => CmpDir::Le,
                    Some("GE") => CmpDir::Ge,
                    Some(other) => {
                        return Err(err(format!("unknown compare direction {other:?}")))
                    }
                    None => return Err(err(format!("{name}: compare without direction"))),
                };
                let (a, na, da) = self.oref(i, 0, slot_of)?;
                let (b, nb, db) = self.oref(i, 1, slot_of)?;
                if da != db || na != nb || na != n {
                    return Err(err(format!(
                        "{name}: mixed shapes/types in compare: {da}[{na}] vs {db}[{nb}] \
                         (result wants {n} elements)"
                    )));
                }
                Ok(Step::Compare {
                    dir,
                    dtype: da,
                    a,
                    b,
                    out,
                    n,
                })
            }
            "select" => {
                let (p, np, dp) = self.oref(i, 0, slot_of)?;
                let (t, nt, dt) = self.oref(i, 1, slot_of)?;
                let (f, nf, df) = self.oref(i, 2, slot_of)?;
                if dp != DType::Pred {
                    return Err(err(format!("expected pred data, got {dp}")));
                }
                if dt != df || nt != nf || nt != n {
                    return Err(err(format!(
                        "{name}: select operands disagree with the result shape \
                         ({nt}/{nf} elements of {dt}/{df}, result wants {n})"
                    )));
                }
                if np != nt && np != 1 {
                    return Err(err(format!(
                        "select predicate has {np} elements, operands have {nt}"
                    )));
                }
                Ok(Step::Select {
                    dtype: dt,
                    p,
                    t,
                    f,
                    out,
                    n,
                    scalar_pred: np == 1 && nt != 1,
                })
            }
            "convert" => {
                let (a, na, da) = self.oref(i, 0, slot_of)?;
                if na != n {
                    return Err(err(format!(
                        "{name}: convert changes element count ({na} -> {n})"
                    )));
                }
                Ok(Step::Convert {
                    from: da,
                    to: self.dtypes[i],
                    a,
                    out,
                    n,
                })
            }
            "broadcast" => {
                let (src, _, da) = self.oref(i, 0, slot_of)?;
                let in_dims = self.odims(i, 0).to_vec();
                let want = declared_dense(ins)?;
                let mapping = &ins.attrs.dimensions;
                if mapping.len() != in_dims.len() {
                    return Err(err(format!(
                        "broadcast dimensions {:?} do not cover operand rank {}",
                        mapping,
                        in_dims.len()
                    )));
                }
                for (ix, &od) in mapping.iter().enumerate() {
                    if od >= want.dims.len()
                        || (want.dims[od] != in_dims[ix] && in_dims[ix] != 1)
                    {
                        return Err(err(format!(
                            "broadcast maps operand dim {ix} (size {}) to output dim {od} of {want}",
                            in_dims[ix]
                        )));
                    }
                }
                let out_st = strides(&want.dims);
                let in_st = strides(&in_dims);
                let map: Vec<u32> = (0..n)
                    .map(|flat| {
                        let c = coords_of(flat, &want.dims, &out_st);
                        let mut inf = 0usize;
                        for (ix, &od) in mapping.iter().enumerate() {
                            let ci = if in_dims[ix] == 1 { 0 } else { c[od] };
                            inf += ci * in_st[ix];
                        }
                        inf as u32
                    })
                    .collect();
                Ok(Step::Gather {
                    dtype: da,
                    src,
                    map,
                    out,
                })
            }
            "transpose" => {
                let (src, _, da) = self.oref(i, 0, slot_of)?;
                let in_dims = self.odims(i, 0).to_vec();
                let perm = &ins.attrs.dimensions;
                if perm.len() != in_dims.len() || perm.iter().any(|&p| p >= in_dims.len()) {
                    return Err(err(format!(
                        "transpose permutation {:?} is not a permutation of rank {}",
                        perm,
                        in_dims.len()
                    )));
                }
                let out_dims: Vec<usize> = perm.iter().map(|&p| in_dims[p]).collect();
                let out_st = strides(&out_dims);
                let in_st = strides(&in_dims);
                let map: Vec<u32> = (0..n)
                    .map(|flat| {
                        let c = coords_of(flat, &out_dims, &out_st);
                        let mut inf = 0usize;
                        for (ix, &p) in perm.iter().enumerate() {
                            inf += c[ix] * in_st[p];
                        }
                        inf as u32
                    })
                    .collect();
                Ok(Step::Gather {
                    dtype: da,
                    src,
                    map,
                    out,
                })
            }
            "slice" => {
                let (src, _, da) = self.oref(i, 0, slot_of)?;
                let in_dims = self.odims(i, 0).to_vec();
                let spec = &ins.attrs.slice;
                if spec.len() != in_dims.len() {
                    return Err(err(format!(
                        "slice spec rank {} does not match operand rank {}",
                        spec.len(),
                        in_dims.len()
                    )));
                }
                let mut out_dims = Vec::with_capacity(spec.len());
                for (d, &(start, limit, stride)) in spec.iter().enumerate() {
                    if stride <= 0 || start < 0 || limit < start || limit as usize > in_dims[d]
                    {
                        return Err(err(format!(
                            "invalid slice [{start}:{limit}:{stride}] for dimension of size {}",
                            in_dims[d]
                        )));
                    }
                    out_dims.push(((limit - start) as usize).div_ceil(stride as usize));
                }
                let out_st = strides(&out_dims);
                let in_st = strides(&in_dims);
                let map: Vec<u32> = (0..n)
                    .map(|flat| {
                        let c = coords_of(flat, &out_dims, &out_st);
                        let mut inf = 0usize;
                        for (d, &(start, _, stride)) in spec.iter().enumerate() {
                            inf += (start as usize + c[d] * stride as usize) * in_st[d];
                        }
                        inf as u32
                    })
                    .collect();
                Ok(Step::Gather {
                    dtype: da,
                    src,
                    map,
                    out,
                })
            }
            "pad" => {
                let (src, _, da) = self.oref(i, 0, slot_of)?;
                let (fill, nf, df) = self.oref(i, 1, slot_of)?;
                if nf != 1 || !self.odims(i, 1).is_empty() {
                    return Err(err("pad fill value must be a scalar".into()));
                }
                if df != da {
                    return Err(err("pad fill dtype mismatch".into()));
                }
                let in_dims = self.odims(i, 0).to_vec();
                let spec = &ins.attrs.padding;
                if spec.len() != in_dims.len() {
                    return Err(err(format!(
                        "padding spec rank {} does not match operand rank {}",
                        spec.len(),
                        in_dims.len()
                    )));
                }
                let mut out_dims = Vec::with_capacity(spec.len());
                for (d, &(lo, hi, interior)) in spec.iter().enumerate() {
                    if interior < 0 {
                        return Err(err("negative interior padding".into()));
                    }
                    let nd = in_dims[d] as i64;
                    let stretched = if nd == 0 { 0 } else { nd + (nd - 1) * interior };
                    let total = lo + stretched + hi;
                    if total < 0 {
                        return Err(err(format!("padding {lo}_{hi} collapses dimension {d}")));
                    }
                    out_dims.push(total as usize);
                }
                let in_st = strides(&in_dims);
                let out_st = strides(&out_dims);
                let mut map = vec![u32::MAX; elements(&out_dims)];
                'next: for flat in 0..elements(&in_dims) {
                    let c = coords_of(flat, &in_dims, &in_st);
                    let mut of = 0usize;
                    for (d, &(lo, _, interior)) in spec.iter().enumerate() {
                        let pos = lo + c[d] as i64 * (1 + interior);
                        if pos < 0 || pos as usize >= out_dims[d] {
                            continue 'next; // cropped away by negative padding
                        }
                        of += pos as usize * out_st[d];
                    }
                    map[of] = flat as u32;
                }
                Ok(Step::Pad {
                    dtype: da,
                    src,
                    fill,
                    map,
                    out,
                })
            }
            "concatenate" => {
                if ins.operands.is_empty() {
                    return Err(err("concatenate with no operands".into()));
                }
                let dim = ins.attrs.dimensions.first().copied().unwrap_or(0);
                let d0 = self.odims(i, 0).to_vec();
                if dim >= d0.len() {
                    return Err(err(format!(
                        "concatenate dimension {dim} out of range for rank {}",
                        d0.len()
                    )));
                }
                let (_, _, dtype) = self.oref(i, 0, slot_of)?;
                let out_dims = self.dims[i].clone();
                let out_st = strides(&out_dims);
                let mut parts = Vec::with_capacity(ins.operands.len());
                let mut offset = 0usize;
                for op_ix in 0..ins.operands.len() {
                    let (r, _, dt) = self.oref(i, op_ix, slot_of)?;
                    let d = self.odims(i, op_ix).to_vec();
                    if d.len() != d0.len() || dt != dtype {
                        return Err(err("concatenate operand shape/type mismatch".into()));
                    }
                    let st = strides(&d);
                    let place: Vec<u32> = (0..elements(&d))
                        .map(|flat| {
                            let mut c = coords_of(flat, &d, &st);
                            c[dim] += offset;
                            let of: usize = c.iter().zip(&out_st).map(|(&ci, &si)| ci * si).sum();
                            of as u32
                        })
                        .collect();
                    offset += d[dim];
                    parts.push((r, place));
                }
                Ok(Step::Concat {
                    dtype,
                    parts,
                    out,
                    n,
                })
            }
            "reverse" => {
                let (src, _, da) = self.oref(i, 0, slot_of)?;
                let in_dims = self.odims(i, 0).to_vec();
                let dims_attr = &ins.attrs.dimensions;
                if dims_attr.iter().any(|&d| d >= in_dims.len()) {
                    return Err(err(format!(
                        "{name}: reverse dimensions {dims_attr:?} out of range for rank {}",
                        in_dims.len()
                    )));
                }
                if elements(&in_dims) != n {
                    return Err(err(format!(
                        "{name}: reverse operand has {} elements, result wants {n}",
                        elements(&in_dims)
                    )));
                }
                let st = strides(&in_dims);
                let map: Vec<u32> = (0..n)
                    .map(|flat| {
                        let mut c = coords_of(flat, &in_dims, &st);
                        for &d in dims_attr {
                            c[d] = in_dims[d] - 1 - c[d];
                        }
                        let inf: usize = c.iter().zip(&st).map(|(&ci, &si)| ci * si).sum();
                        inf as u32
                    })
                    .collect();
                Ok(Step::Gather {
                    dtype: da,
                    src,
                    map,
                    out,
                })
            }
            "dynamic-slice" => {
                let (src, _, da) = self.oref(i, 0, slot_of)?;
                let src_dims = self.odims(i, 0).to_vec();
                let sizes = ins.attrs.dynamic_slice_sizes.clone();
                if sizes.len() != src_dims.len() {
                    return Err(err(format!(
                        "{name}: dynamic_slice_sizes {sizes:?} do not match operand rank {}",
                        src_dims.len()
                    )));
                }
                if sizes.iter().zip(&src_dims).any(|(&s, &d)| s > d) {
                    return Err(err(format!(
                        "{name}: dynamic-slice sizes {sizes:?} exceed operand dims {src_dims:?}"
                    )));
                }
                if elements(&sizes) != n {
                    return Err(err(format!(
                        "{name}: dynamic-slice sizes {sizes:?} disagree with the result \
                         ({n} elements)"
                    )));
                }
                let starts = self.start_indices(i, 1, src_dims.len(), slot_of)?;
                Ok(Step::DynSlice {
                    dtype: da,
                    src,
                    starts,
                    src_dims,
                    sizes,
                    out,
                })
            }
            "dynamic-update-slice" => {
                let (src, ns, da) = self.oref(i, 0, slot_of)?;
                let (upd, _, du) = self.oref(i, 1, slot_of)?;
                let src_dims = self.odims(i, 0).to_vec();
                let upd_dims = self.odims(i, 1).to_vec();
                if du != da {
                    return Err(err(format!(
                        "{name}: dynamic-update-slice update dtype {du} does not match \
                         operand {da}"
                    )));
                }
                if upd_dims.len() != src_dims.len()
                    || upd_dims.iter().zip(&src_dims).any(|(&u, &s)| u > s)
                {
                    return Err(err(format!(
                        "{name}: update shape {upd_dims:?} does not fit operand {src_dims:?}"
                    )));
                }
                if ns != n {
                    return Err(err(format!(
                        "{name}: dynamic-update-slice result wants {n} elements, operand \
                         has {ns}"
                    )));
                }
                let starts = self.start_indices(i, 2, src_dims.len(), slot_of)?;
                Ok(Step::DynUpdate {
                    dtype: da,
                    src,
                    upd,
                    starts,
                    src_dims,
                    upd_dims,
                    out,
                })
            }
            "call" => {
                let (callee, args) = self.lower_call_common(i, slot_of)?;
                let want = Shape {
                    dtype: self.dtypes[i],
                    dims: self.dims[i].clone(),
                };
                check_sub_outputs(name, "call target", &callee, std::slice::from_ref(&want))?;
                Ok(Step::Call {
                    callee,
                    args,
                    outs: vec![out],
                })
            }
            "while" => Err(err(format!(
                "{name}: while with non-tuple state is not supported"
            ))),
            "convolution" => self.lower_conv(i, out, slot_of, conv_scratch),
            "dot" => self.lower_dot(i, out, slot_of),
            "reduce" => self.lower_reduce(i, out, slot_of),
            // Every dtype-correct elementwise case was consumed above (or
            // by the fusable() early return); reaching here with a known
            // elementwise opcode means the dtype does not support it.
            "add" | "subtract" | "multiply" | "divide" | "maximum" | "minimum" | "power"
            | "remainder" | "and" | "or" | "xor" | "abs" | "negate" | "exponential"
            | "exponential-minus-one" | "log" | "log-plus-one" | "logistic" | "tanh" | "sqrt"
            | "rsqrt" | "sign" | "floor" | "ceil" | "cosine" | "sine" | "not" | "copy" => {
                Err(err(format!(
                    "op {:?} not defined for {}",
                    ins.op, self.dtypes[i]
                )))
            }
            other => Err(err(format!(
                "opcode {other:?} (instruction {name}) passed the parse-time allow-list \
                 but has no compiled lowering — parse.rs SUPPORTED and program.rs are \
                 out of sync"
            ))),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn check_binary(
        &self,
        name: &str,
        op: &str,
        na: usize,
        da: DType,
        nb: usize,
        db: DType,
        n: usize,
        want: DType,
    ) -> Result<()> {
        if da != db {
            return Err(err(format!(
                "mixed element types in {op:?}: {da} vs {db}"
            )));
        }
        if da != want {
            return Err(err(format!("op {op:?} not defined for {da}")));
        }
        if na != nb || na != n {
            return Err(err(format!(
                "{name}: shape mismatch in elementwise op: {na} vs {nb} elements"
            )));
        }
        Ok(())
    }

    fn check_unary(
        &self,
        name: &str,
        op: &str,
        na: usize,
        da: DType,
        n: usize,
        want: DType,
    ) -> Result<()> {
        if da != want {
            return Err(err(format!("op {op:?} not defined for {da}")));
        }
        if na != n {
            return Err(err(format!(
                "{name}: unary operand has {na} elements, result wants {n}"
            )));
        }
        Ok(())
    }

    /// Post-order collection of the fused group rooted at `head`.
    fn collect_group(&self, head: usize, out: u32, slot_of: &[u32]) -> Result<FusedLoop> {
        let mut inputs: Vec<Ref> = Vec::new();
        let mut ops: Vec<LaneOp> = Vec::new();
        // reg index per inlined/head SSA value.
        let mut reg_of: Vec<Option<u8>> = vec![None; self.comp.instrs.len()];
        self.collect_into(head, &mut inputs, &mut ops, &mut reg_of, slot_of)?;
        let n = elements(&self.dims[head]);
        debug_assert!(ops.len() <= MAX_FUSED_OPS && inputs.len() <= MAX_FUSED_INPUTS);
        Ok(FusedLoop {
            n,
            inputs,
            ops,
            out,
        })
    }

    fn collect_into(
        &self,
        i: usize,
        inputs: &mut Vec<Ref>,
        ops: &mut Vec<LaneOp>,
        reg_of: &mut Vec<Option<u8>>,
        slot_of: &[u32],
    ) -> Result<u8> {
        let ins = &self.comp.instrs[i];
        let (op, binary) = EwOp::from_name(&ins.op).expect("fusable op");
        let mut lanes: Vec<Lane> = Vec::with_capacity(2);
        let arity = if binary { 2 } else { 1 };
        for op_ix in 0..arity {
            let o = ins.operands[op_ix];
            let r = self.resolve(o);
            // Elementwise operands must match the result's element count.
            if elements(&self.dims[o]) != elements(&self.dims[i]) {
                return Err(err(format!(
                    "{}: shape mismatch in elementwise op: {} vs {} elements",
                    ins.name,
                    elements(&self.dims[o]),
                    elements(&self.dims[i])
                )));
            }
            let lane = if matches!(self.kinds[r], Kind::Inst) && self.inlined[r] {
                let reg = match reg_of[r] {
                    Some(reg) => reg,
                    None => self.collect_into(r, inputs, ops, reg_of, slot_of)?,
                };
                Lane::Reg(reg)
            } else {
                let rf = self.ssa_ref(r, slot_of);
                let ix = match inputs.iter().position(|&x| x == rf) {
                    Some(ix) => ix,
                    None => {
                        inputs.push(rf);
                        inputs.len() - 1
                    }
                };
                Lane::In(ix as u8)
            };
            lanes.push(lane);
        }
        ops.push(LaneOp {
            op,
            a: lanes[0],
            b: lanes.get(1).copied(),
        });
        let reg = (ops.len() - 1) as u8;
        reg_of[i] = Some(reg);
        Ok(reg)
    }

    fn lower_dot(&self, i: usize, out: u32, slot_of: &[u32]) -> Result<Step> {
        let ins = &self.comp.instrs[i];
        let attrs = &ins.attrs;
        if attrs.lhs_contracting.len() != 1 || attrs.rhs_contracting.len() != 1 {
            return Err(err(
                "dot requires exactly one contracting dimension per side".into(),
            ));
        }
        let (lc, rc) = (attrs.lhs_contracting[0], attrs.rhs_contracting[0]);
        let (lhs, _, dl) = self.oref(i, 0, slot_of)?;
        let (rhs, _, dr) = self.oref(i, 1, slot_of)?;
        if dl != DType::F32 {
            return Err(err(format!("expected f32 data, got {dl}")));
        }
        if dr != DType::F32 {
            return Err(err(format!("expected f32 data, got {dr}")));
        }
        let ld = self.odims(i, 0).to_vec();
        let rd = self.odims(i, 1).to_vec();
        if lc >= ld.len() || rc >= rd.len() || ld[lc] != rd[rc] {
            return Err(err(format!(
                "dot contraction mismatch: lhs dim {lc} of {ld:?} vs rhs dim {rc} of {rd:?}"
            )));
        }
        let lb = &attrs.lhs_batch;
        let rb = &attrs.rhs_batch;
        if lb.len() != rb.len() {
            return Err(err("dot batch dimension ranks disagree".into()));
        }
        for (&a, &c) in lb.iter().zip(rb.iter()) {
            if a >= ld.len() || c >= rd.len() || ld[a] != rd[c] || a == lc || c == rc {
                return Err(err(format!(
                    "dot batch dimension mismatch: lhs dim {a} of {ld:?} vs rhs dim {c} of {rd:?}"
                )));
            }
        }
        let k = ld[lc];
        let batch_dims: Vec<usize> = lb.iter().map(|&d| ld[d]).collect();
        let b = elements(&batch_dims);
        let b_st = strides(&batch_dims);
        let lfree: Vec<usize> = (0..ld.len())
            .filter(|&d| d != lc && !lb.contains(&d))
            .collect();
        let rfree: Vec<usize> = (0..rd.len())
            .filter(|&d| d != rc && !rb.contains(&d))
            .collect();
        let l_st = strides(&ld);
        let r_st = strides(&rd);
        let lfree_dims: Vec<usize> = lfree.iter().map(|&d| ld[d]).collect();
        let rfree_dims: Vec<usize> = rfree.iter().map(|&d| rd[d]).collect();
        let m = elements(&lfree_dims);
        let n = elements(&rfree_dims);
        let lf_st = strides(&lfree_dims);
        let rf_st = strides(&rfree_dims);
        if elements(&self.dims[i]) != b * m * n {
            return Err(err(format!(
                "dot output {:?} disagrees with its batch/free geometry",
                self.dims[i]
            )));
        }
        let mut l_base = Vec::with_capacity(b * m);
        let mut r_base = Vec::with_capacity(b * n);
        for bx in 0..b {
            let bc = coords_of(bx, &batch_dims, &b_st);
            let mut l_off = 0usize;
            let mut r_off = 0usize;
            for (ix, (&a, &c)) in lb.iter().zip(rb.iter()).enumerate() {
                l_off += bc[ix] * l_st[a];
                r_off += bc[ix] * r_st[c];
            }
            for flat in 0..m {
                let c = coords_of(flat, &lfree_dims, &lf_st);
                let mut base = l_off;
                for (ix, &d) in lfree.iter().enumerate() {
                    base += c[ix] * l_st[d];
                }
                l_base.push(base as u32);
            }
            for flat in 0..n {
                let c = coords_of(flat, &rfree_dims, &rf_st);
                let mut base = r_off;
                for (ix, &d) in rfree.iter().enumerate() {
                    base += c[ix] * r_st[d];
                }
                r_base.push(base as u32);
            }
        }
        // Iota only if EVERY batch slice's column bases are the identity
        // (algorithms the picker gates on this assume contiguous rhs rows).
        let r_base_is_iota = r_base
            .iter()
            .enumerate()
            .all(|(j, &v)| v as usize == j % n.max(1));
        let algo = cost::select_dot_algo(m, n, k, l_st[lc], r_st[rc], r_base_is_iota);
        Ok(Step::Dot(DotPlan {
            lhs,
            rhs,
            out,
            b,
            m,
            n,
            k,
            l_base,
            r_base,
            l_kstride: l_st[lc],
            r_kstride: r_st[rc],
            algo,
        }))
    }

    fn lower_reduce(&self, i: usize, out: u32, slot_of: &[u32]) -> Result<Step> {
        let ins = &self.comp.instrs[i];
        let (data, _, dd) = self.oref(i, 0, slot_of)?;
        let (init, ni, di) = self.oref(i, 1, slot_of)?;
        if dd != DType::F32 {
            return Err(err(format!(
                "reduce over {dd} is not supported by the interp backend"
            )));
        }
        if di != DType::F32 || ni != 1 {
            return Err(err(format!("expected a scalar, got {ni} elements")));
        }
        let dims = self.odims(i, 0).to_vec();
        let red = &ins.attrs.dimensions;
        let keep: Vec<usize> = (0..dims.len()).filter(|d| !red.contains(d)).collect();
        let out_dims: Vec<usize> = keep.iter().map(|&d| dims[d]).collect();
        let out_elems = elements(&out_dims);
        let st = strides(&dims);
        let out_st = strides(&out_dims);
        let map: Vec<u32> = (0..elements(&dims))
            .map(|flat| {
                let c = coords_of(flat, &dims, &st);
                let mut of = 0usize;
                for (kx, &d) in keep.iter().enumerate() {
                    of += c[d] * out_st[kx];
                }
                of as u32
            })
            .collect();
        let comp_name = ins
            .attrs
            .to_apply
            .as_deref()
            .ok_or_else(|| err("reduce without to_apply".into()))?;
        let region = compile_region(self.module.computation(comp_name)?)?;
        let algo =
            cost::select_reduce_algo(&map, out_elems, matches!(region, RegionFn::Add));
        Ok(Step::Reduce(ReducePlan {
            data,
            init,
            out,
            out_elems,
            map,
            region,
            algo,
        }))
    }

    /// Compile a sub-computation (while condition/body, call target) into
    /// its own [`Program`].
    fn compile_sub(&self, name: &str) -> Result<Arc<Program>> {
        let comp = self.module.computation(name)?;
        Ok(Arc::new(Program::compile_computation(
            self.module,
            comp,
            false,
            self.depth + 1,
        )?))
    }

    /// Validate and resolve the scalar s32 start-index operands of
    /// dynamic-slice / dynamic-update-slice.
    fn start_indices(
        &self,
        i: usize,
        first: usize,
        rank: usize,
        slot_of: &[u32],
    ) -> Result<Vec<Ref>> {
        let ins = &self.comp.instrs[i];
        if ins.operands.len() != first + rank {
            return Err(err(format!(
                "{}: expected {rank} start indices, got {}",
                ins.name,
                ins.operands.len().saturating_sub(first)
            )));
        }
        let mut starts = Vec::with_capacity(rank);
        for ox in first..first + rank {
            let (r, nn, dt) = self.oref(i, ox, slot_of)?;
            if dt != DType::S32 || nn != 1 {
                return Err(err(format!(
                    "{}: start index {} must be a scalar s32, got {dt}[{nn}]",
                    ins.name,
                    ox - first
                )));
            }
            starts.push(r);
        }
        Ok(starts)
    }

    /// Compile a call target and resolve its argument refs (shared by the
    /// dense and tuple-result lowerings).
    fn lower_call_common(&self, i: usize, slot_of: &[u32]) -> Result<(Arc<Program>, Vec<Ref>)> {
        let ins = &self.comp.instrs[i];
        let name = &ins.name;
        let target = ins
            .attrs
            .to_apply
            .as_deref()
            .ok_or_else(|| err(format!("{name}: call without to_apply")))?;
        let callee = self.compile_sub(target)?;
        if callee.params.len() != ins.operands.len() {
            return Err(err(format!(
                "{name}: call target {target:?} takes {} parameters, got {} operands",
                callee.params.len(),
                ins.operands.len()
            )));
        }
        let mut args = Vec::with_capacity(ins.operands.len());
        for (ox, p) in callee.params.iter().enumerate() {
            let (r, nn, dt) = self.oref(i, ox, slot_of)?;
            if dt != p.dtype || nn != elements(&p.dims) {
                return Err(err(format!(
                    "{name}: call argument {ox} is {dt}[{nn}], target {target:?} wants \
                     {}[{}]",
                    p.dtype,
                    elements(&p.dims)
                )));
            }
            args.push(r);
        }
        Ok((callee, args))
    }

    /// Build the step for a multi-output instruction (`while`, tuple
    /// `call`) writing one slot per tuple element.
    fn lower_multi(&self, i: usize, outs: Vec<u32>, slot_of: &[u32]) -> Result<Step> {
        let ins = &self.comp.instrs[i];
        let parts = self.multi_shapes[&i].clone();
        match ins.op.as_str() {
            "while" => self.lower_while(i, &parts, outs, slot_of),
            "call" => {
                let (callee, args) = self.lower_call_common(i, slot_of)?;
                check_sub_outputs(&ins.name, "call target", &callee, &parts)?;
                Ok(Step::Call { callee, args, outs })
            }
            other => Err(err(format!(
                "{}: tuple-shaped {other:?} is not supported",
                ins.name
            ))),
        }
    }

    fn lower_while(
        &self,
        i: usize,
        parts: &[Shape],
        outs: Vec<u32>,
        slot_of: &[u32],
    ) -> Result<Step> {
        let ins = &self.comp.instrs[i];
        let name = &ins.name;
        let cond_name = ins
            .attrs
            .condition
            .as_deref()
            .ok_or_else(|| err(format!("{name}: while without condition")))?;
        let body_name = ins
            .attrs
            .body
            .as_deref()
            .ok_or_else(|| err(format!("{name}: while without body")))?;
        let cond = self.compile_sub(cond_name)?;
        let body = self.compile_sub(body_name)?;
        let raw = self.while_init_parts(i)?;
        if raw.len() != parts.len() {
            return Err(err(format!(
                "{name}: while state has {} elements, result declares {}",
                raw.len(),
                parts.len()
            )));
        }
        let mut init = Vec::with_capacity(raw.len());
        for (kx, (&p, want)) in raw.iter().zip(parts).enumerate() {
            let (dt, nn) = (self.dtypes[p], elements(&self.dims[p]));
            if dt != want.dtype || nn != want.elements() {
                return Err(err(format!(
                    "{name}: while state element {kx} is {dt}[{nn}], result declares {want}"
                )));
            }
            init.push(self.ssa_ref(self.resolve(p), slot_of));
        }
        check_sub_params(name, "while condition", &cond, parts)?;
        check_sub_params(name, "while body", &body, parts)?;
        let co = &cond.outputs;
        if co.len() != 1 || co[0].dtype != DType::Pred || !co[0].dims.is_empty() {
            return Err(err(format!(
                "{name}: while condition {cond_name:?} must return a scalar pred"
            )));
        }
        check_sub_outputs(name, "while body", &body, parts)?;
        Ok(Step::While {
            cond,
            body,
            init,
            outs,
        })
    }

    /// Validated compile-time geometry of a convolution (shared by the
    /// scratch-slot sizing pass and the full lowering).
    fn conv_geometry(&self, i: usize) -> Result<ConvGeom> {
        let ins = &self.comp.instrs[i];
        let name = &ins.name;
        let attrs = &ins.attrs;
        if ins.operands.len() != 2 {
            return Err(err(format!(
                "{name}: convolution takes exactly two operands"
            )));
        }
        let dl = attrs
            .dim_labels
            .as_deref()
            .ok_or_else(|| err(format!("{name}: convolution without dim_labels")))?;
        let (in_seg, rest) = dl
            .split_once('_')
            .ok_or_else(|| err(format!("{name}: malformed dim_labels {dl:?}")))?;
        let (ker_seg, out_seg) = rest
            .split_once("->")
            .ok_or_else(|| err(format!("{name}: malformed dim_labels {dl:?}")))?;
        let in_ord = parse_dim_order(in_seg, 'b', 'f', "input")?;
        let ker_ord = parse_dim_order(ker_seg, 'i', 'o', "kernel")?;
        let out_ord = parse_dim_order(out_seg, 'b', 'f', "output")?;
        let in_dims = self.odims(i, 0);
        let ker_dims = self.odims(i, 1);
        let out_dims = &self.dims[i];
        let s = in_ord.sp.len();
        if ker_ord.sp.len() != s || out_ord.sp.len() != s {
            return Err(err(format!(
                "{name}: dim_labels {dl:?} spatial ranks disagree"
            )));
        }
        if in_dims.len() != s + 2 || ker_dims.len() != s + 2 || out_dims.len() != s + 2 {
            return Err(err(format!(
                "{name}: dim_labels {dl:?} do not match operand/result ranks"
            )));
        }
        if attrs.window.len() != s {
            return Err(err(format!(
                "{name}: window has {} dimensions, dim_labels {dl:?} want {s}",
                attrs.window.len()
            )));
        }
        if attrs.batch_group_count.unwrap_or(1) != 1 {
            return Err(err(format!(
                "{name}: batch_group_count > 1 is not supported"
            )));
        }
        let groups = attrs.feature_group_count.unwrap_or(1).max(1);
        let batch = in_dims[in_ord.b];
        let ci = in_dims[in_ord.f];
        let ki = ker_dims[ker_ord.b];
        let ko = ker_dims[ker_ord.f];
        if ci != groups * ki || ko % groups != 0 {
            return Err(err(format!(
                "{name}: feature_group_count {groups} does not partition input features \
                 {ci} (kernel wants {ki} per group) / output features {ko}"
            )));
        }
        if out_dims[out_ord.b] != batch || out_dims[out_ord.f] != ko {
            return Err(err(format!(
                "{name}: declared output batch/features disagree with the operands"
            )));
        }
        let in_spatial: Vec<usize> = in_ord.sp.iter().map(|&p| in_dims[p]).collect();
        let ker_spatial: Vec<usize> = ker_ord.sp.iter().map(|&p| ker_dims[p]).collect();
        let mut out_spatial = Vec::with_capacity(s);
        for d in 0..s {
            let w = &attrs.window[d];
            if w.stride == 0 {
                return Err(err(format!("{name}: window stride 0")));
            }
            if w.size == 0 || w.window_dilation == 0 || w.base_dilation == 0 {
                return Err(err(format!(
                    "{name}: window size/dilation 0 in spatial dim {d}"
                )));
            }
            if w.size != ker_spatial[d] {
                return Err(err(format!(
                    "{name}: window size {} disagrees with kernel spatial dim {}",
                    w.size, ker_spatial[d]
                )));
            }
            let extent = ((w.size - 1) * w.window_dilation + 1) as i64;
            // lhs_dilate (transposed convolution, e.g. the input-gradient
            // conv of a strided forward conv): the input is virtually
            // interior-dilated to (n-1)*base + 1 taps; positions landing
            // between real taps become u32::MAX halo entries in the patch
            // map below, zero-filled exactly like padding.
            let dilated = match in_spatial[d] {
                0 => 0,
                n => (n - 1) * w.base_dilation + 1,
            };
            let padded = dilated as i64 + w.pad_lo + w.pad_hi;
            if padded < extent {
                return Err(err(format!(
                    "{name}: window does not fit padded spatial dim {d} \
                     ({padded} < {extent})"
                )));
            }
            let o = ((padded - extent) / w.stride as i64 + 1) as usize;
            if out_dims[out_ord.sp[d]] != o {
                return Err(err(format!(
                    "{name}: declared output spatial dim {d} is {}, window math gives {o}",
                    out_dims[out_ord.sp[d]]
                )));
            }
            out_spatial.push(o);
        }
        Ok(ConvGeom {
            in_ord,
            ker_ord,
            out_ord,
            groups,
            ki,
            ng: ko / groups,
            in_spatial,
            ker_spatial,
            m: batch * elements(&out_spatial),
            k: elements(&ker_spatial) * ki,
            out_spatial,
        })
    }

    fn lower_conv(
        &self,
        i: usize,
        out: u32,
        slot_of: &[u32],
        scratch: Option<[u32; 3]>,
    ) -> Result<Step> {
        let ins = &self.comp.instrs[i];
        let name = &ins.name;
        let (lhs, _, dl) = self.oref(i, 0, slot_of)?;
        let (rhs, _, dr) = self.oref(i, 1, slot_of)?;
        if dl != DType::F32 || dr != DType::F32 {
            return Err(err(format!(
                "{name}: convolution is f32-only on the interp backend"
            )));
        }
        let g = self.conv_geometry(i)?;
        let conv_algo = conv_algo_for(&g);
        let scratch = match conv_algo {
            // The fused blocked kernel materializes nothing.
            cost::ConvAlgo::Blocked => None,
            cost::ConvAlgo::Im2col => {
                Some(scratch.expect("conv scratch reserved for im2col convolution programs"))
            }
        };
        let in_st = strides(self.odims(i, 0));
        let ker_st = strides(self.odims(i, 1));
        let out_st = strides(&self.dims[i]);
        let osp_st = strides(&g.out_spatial);
        let ksp_st = strides(&g.ker_spatial);
        let osp_elems = elements(&g.out_spatial);
        let window = &ins.attrs.window;
        let s = g.out_spatial.len();
        let (m, k, ng) = (g.m, g.k, g.ng);
        let mut groups = Vec::with_capacity(g.groups);
        for gx in 0..g.groups {
            // Patch column order: kernel spatial coords, then the
            // group-local input feature (fastest).
            let mut patch_map = vec![u32::MAX; m * k];
            for r in 0..m {
                let b = r / osp_elems;
                let oc = coords_of(r % osp_elems, &g.out_spatial, &osp_st);
                for c in 0..k {
                    let kc = coords_of(c / g.ki, &g.ker_spatial, &ksp_st);
                    let fi = c % g.ki;
                    let mut flat =
                        b * in_st[g.in_ord.b] + (gx * g.ki + fi) * in_st[g.in_ord.f];
                    let mut inside = true;
                    for d in 0..s {
                        let w = &window[d];
                        // Window position in the (virtually) lhs-dilated
                        // coordinate system; real input taps sit at
                        // multiples of base_dilation, everything else is
                        // an interior zero -> halo entry.
                        let iy = oc[d] as i64 * w.stride as i64 - w.pad_lo
                            + kc[d] as i64 * w.window_dilation as i64;
                        let base = w.base_dilation as i64;
                        if iy < 0 || iy % base != 0 || (iy / base) as usize >= g.in_spatial[d]
                        {
                            inside = false;
                            break;
                        }
                        flat += (iy / base) as usize * in_st[g.in_ord.sp[d]];
                    }
                    if inside {
                        patch_map[r * k + c] = flat as u32;
                    }
                }
            }
            let mut w_map = vec![0u32; k * ng];
            for c in 0..k {
                let kc = coords_of(c / g.ki, &g.ker_spatial, &ksp_st);
                let fi = c % g.ki;
                for j in 0..ng {
                    let mut flat =
                        fi * ker_st[g.ker_ord.b] + (gx * ng + j) * ker_st[g.ker_ord.f];
                    for d in 0..s {
                        flat += kc[d] * ker_st[g.ker_ord.sp[d]];
                    }
                    w_map[c * ng + j] = flat as u32;
                }
            }
            let mut place = vec![0u32; m * ng];
            for r in 0..m {
                let b = r / osp_elems;
                let oc = coords_of(r % osp_elems, &g.out_spatial, &osp_st);
                for j in 0..ng {
                    let mut flat =
                        b * out_st[g.out_ord.b] + (gx * ng + j) * out_st[g.out_ord.f];
                    for d in 0..s {
                        flat += oc[d] * out_st[g.out_ord.sp[d]];
                    }
                    place[r * ng + j] = flat as u32;
                }
            }
            groups.push(ConvGroup {
                patch_map,
                w_map,
                place,
            });
        }
        // The im2col dot is row-major [m,k] x [k,ng]: contiguous k on the
        // left (stride 1), iota column bases on the right (stride ng).
        let l_base: Vec<u32> = (0..m).map(|r| (r * k) as u32).collect();
        let r_base: Vec<u32> = (0..ng).map(|j| j as u32).collect();
        let algo = cost::select_dot_algo(m, ng, k, 1, ng, true);
        Ok(Step::Conv(ConvPlan {
            lhs,
            rhs,
            out,
            m,
            k,
            ng,
            groups,
            scratch,
            l_base,
            r_base,
            algo,
            conv_algo,
        }))
    }
}

/// Resolved conv strategy for one conv: the `DIVEBATCH_CONV_ALGO`
/// override (`blocked` / `im2col`) when set, else the cost model.  Read
/// fresh at every compile, never cached — the perf bench compiles the
/// same module under both values.  Strategy only (the pinned lanes
/// contract keeps both arms bit-identical), so unknown values simply
/// fall through to the cost model.
fn conv_algo_for(g: &ConvGeom) -> cost::ConvAlgo {
    match std::env::var("DIVEBATCH_CONV_ALGO").as_deref() {
        Ok("blocked") => cost::ConvAlgo::Blocked,
        Ok("im2col") => cost::ConvAlgo::Im2col,
        _ => cost::select_conv_algo(g.m, g.k, g.ng, g.groups),
    }
}

/// Positions of the batch/feature/spatial dims in one `dim_labels`
/// segment (`b01f`-style; the kernel segment maps `i`/`o` to b/f here).
struct DimOrder {
    b: usize,
    f: usize,
    /// Spatial digit -> dim position.
    sp: Vec<usize>,
}

/// Compile-time geometry of a convolution.
struct ConvGeom {
    in_ord: DimOrder,
    ker_ord: DimOrder,
    out_ord: DimOrder,
    groups: usize,
    /// Input features per group (the kernel's input-feature dim).
    ki: usize,
    /// Output features per group.
    ng: usize,
    in_spatial: Vec<usize>,
    ker_spatial: Vec<usize>,
    out_spatial: Vec<usize>,
    /// Patch rows: batch x output spatial positions.
    m: usize,
    /// Patch columns: kernel spatial positions x input features per group.
    k: usize,
}

fn parse_dim_order(seg: &str, bc: char, fc: char, what: &str) -> Result<DimOrder> {
    let mut b = None;
    let mut f = None;
    let mut sp: Vec<Option<usize>> = Vec::new();
    for (pos, c) in seg.chars().enumerate() {
        if c == bc {
            if b.replace(pos).is_some() {
                return Err(err(format!("dim_labels {what} segment repeats {bc:?}")));
            }
        } else if c == fc {
            if f.replace(pos).is_some() {
                return Err(err(format!("dim_labels {what} segment repeats {fc:?}")));
            }
        } else if let Some(d) = c.to_digit(10) {
            let d = d as usize;
            if sp.len() <= d {
                sp.resize(d + 1, None);
            }
            if sp[d].replace(pos).is_some() {
                return Err(err(format!("dim_labels {what} segment repeats digit {d}")));
            }
        } else {
            return Err(err(format!(
                "bad dim_labels character {c:?} in the {what} segment"
            )));
        }
    }
    let b = b.ok_or_else(|| err(format!("dim_labels {what} segment missing {bc:?}")))?;
    let f = f.ok_or_else(|| err(format!("dim_labels {what} segment missing {fc:?}")))?;
    let sp: Vec<usize> = sp
        .into_iter()
        .map(|o| {
            o.ok_or_else(|| {
                err(format!(
                    "dim_labels {what} segment has a gap in its spatial digits"
                ))
            })
        })
        .collect::<Result<_>>()?;
    Ok(DimOrder { b, f, sp })
}

/// Check a sub-program's flattened parameters against expected shapes.
fn check_sub_params(name: &str, what: &str, sub: &Program, want: &[Shape]) -> Result<()> {
    if sub.params.len() != want.len() {
        return Err(err(format!(
            "{name}: {what} {:?} takes {} values, the state has {}",
            sub.entry_name,
            sub.params.len(),
            want.len()
        )));
    }
    for (kx, (p, w)) in sub.params.iter().zip(want).enumerate() {
        if p.dtype != w.dtype || elements(&p.dims) != w.elements() {
            return Err(err(format!(
                "{name}: {what} parameter {kx} is {}[{}], expected {w}",
                p.dtype,
                elements(&p.dims)
            )));
        }
    }
    Ok(())
}

/// Check a sub-program's outputs against expected shapes.
fn check_sub_outputs(name: &str, what: &str, sub: &Program, want: &[Shape]) -> Result<()> {
    if sub.outputs.len() != want.len() {
        return Err(err(format!(
            "{name}: {what} {:?} returns {} values, expected {}",
            sub.entry_name,
            sub.outputs.len(),
            want.len()
        )));
    }
    for (kx, (o, w)) in sub.outputs.iter().zip(want).enumerate() {
        let oe: usize = o.dims.iter().map(|&d| d as usize).product();
        if o.dtype != w.dtype || oe != w.elements() {
            return Err(err(format!(
                "{name}: {what} output {kx} is {}[{oe}], expected {w}",
                o.dtype
            )));
        }
    }
    Ok(())
}

/// Compile a reduce region computation into a [`RegionFn`]: the one-op
/// commutative cases get direct kernels, everything else a scalar register
/// program (the satellite: multi-op regions never fall back to tree
/// re-evaluation).
fn compile_region(comp: &Computation) -> Result<RegionFn> {
    if comp.params.len() != 2 {
        return Err(err(format!(
            "reduce region {:?} takes {} parameters, expected 2",
            comp.name,
            comp.params.len()
        )));
    }
    // One-op fast path (jax emits these): root is a commutative binop over
    // the two parameters.
    if comp.instrs.len() == 3 {
        let root = &comp.instrs[comp.root];
        if root.operands.len() == 2
            && comp.instrs[root.operands[0]].op == "parameter"
            && comp.instrs[root.operands[1]].op == "parameter"
        {
            match root.op.as_str() {
                "add" => return Ok(RegionFn::Add),
                "multiply" => return Ok(RegionFn::Mul),
                "maximum" => return Ok(RegionFn::Max),
                "minimum" => return Ok(RegionFn::Min),
                _ => {}
            }
        }
    }
    // General scalar register program.
    let mut consts: Vec<f32> = Vec::new();
    let mut ops: Vec<ScalarOp> = Vec::new();
    let mut src_of: Vec<Option<ScalarSrc>> = vec![None; comp.instrs.len()];
    for (i, ins) in comp.instrs.iter().enumerate() {
        let s = declared_dense(ins)?;
        if s.dtype != DType::F32 || !s.dims.is_empty() {
            return Err(err(format!(
                "reduce region {:?}: {} is not a scalar f32 (regions are compiled to \
                 scalar register programs)",
                comp.name, ins.name
            )));
        }
        let src = match ins.op.as_str() {
            "parameter" => match ins.param.expect("parameter number") {
                0 => ScalarSrc::Acc,
                1 => ScalarSrc::X,
                p => return Err(err(format!("region parameter {p} out of range"))),
            },
            "constant" => {
                let c = ins.literal.as_ref().expect("parsed constant");
                let ConstPayload::F32(v) = &c.payload else {
                    return Err(err(format!(
                        "reduce region {:?}: non-f32 constant",
                        comp.name
                    )));
                };
                if consts.len() >= MAX_REGION_OPS {
                    return Err(err("reduce region has too many constants".into()));
                }
                consts.push(v[0]);
                ScalarSrc::Const((consts.len() - 1) as u8)
            }
            "reshape" | "copy" => src_of[ins.operands[0]]
                .ok_or_else(|| err(format!("{}: operand used before definition", ins.name)))?,
            opname => {
                let Some((op, binary)) = EwOp::from_name(opname) else {
                    return Err(err(format!(
                        "reduce region {:?}: op {opname:?} is outside the scalar-region \
                         subset",
                        comp.name
                    )));
                };
                let get = |ix: usize| -> Result<ScalarSrc> {
                    let o = *ins
                        .operands
                        .get(ix)
                        .ok_or_else(|| err(format!("{}: missing operand {ix}", ins.name)))?;
                    src_of[o].ok_or_else(|| {
                        err(format!("{}: operand used before definition", ins.name))
                    })
                };
                let a = get(0)?;
                let b = if binary { Some(get(1)?) } else { None };
                if ops.len() >= MAX_REGION_OPS {
                    return Err(err("reduce region has too many ops".into()));
                }
                ops.push(ScalarOp { op, a, b });
                ScalarSrc::Reg((ops.len() - 1) as u8)
            }
        };
        src_of[i] = Some(src);
    }
    let result = src_of[comp.root].expect("root lowered");
    Ok(RegionFn::Program(ScalarProgram {
        ops,
        consts,
        result,
    }))
}
