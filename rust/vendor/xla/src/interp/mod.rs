//! Pure-Rust HLO-text interpreter: the `interp` execution backend.
//!
//! Since PR 4 the interpreter is split into a **compile phase** and an
//! **execute phase**:
//!
//! * [`parse`] — the HLO *text* interchange-format parser (emitted by
//!   python/compile/aot.py via `XlaComputation::as_hlo_text`).  Produces a
//!   [`parse::Module`]: computations, instructions with operand indices
//!   resolved, attributes decoded.  Unsupported opcodes are rejected here,
//!   at compile time, with an error naming the opcode.
//! * [`program`] — lowering: the entry computation is compiled into a flat
//!   SSA "register program" ([`program::Program`]).  Operand names become
//!   dense value-slot indices, shapes/strides/broadcast mappings/reduce
//!   plans are precomputed into per-instruction plan structs, elementwise
//!   ops are monomorphized into typed f32/i32/pred kernels, adjacent f32
//!   elementwise instructions whose intermediates have a single consumer
//!   are fused into single-pass loops, and a last-use liveness analysis
//!   assigns every materialized value a reusable buffer slot.
//! * [`cost`] — the compile-time cost model: picks each dot plan's
//!   execution variant, each convolution's strategy (fused blocked-direct
//!   vs im2col-onto-dot; `DIVEBATCH_CONV_ALGO` overrides it), the
//!   grouped-reduce strategy, and the fusion caps from FLOPs /
//!   bytes-moved / stride-contiguity facts.  Strategy only: every variant
//!   implements the same pinned numeric contract, so the selection never
//!   changes bits.
//! * [`kernels`] — the typed execution kernels, in two tiers
//!   (`DIVEBATCH_INTERP_TIER`, default `simd`): 8-lane blocked f32 loops
//!   with scalar tails (AVX where the CPU has it), register-blocked /
//!   k-outer-axpy dot variants, a fused blocked convolution kernel that
//!   gathers patch tiles straight through the precomputed im2col map (no
//!   patch-matrix materialization), grouped-lanes reduce, and gather-map
//!   data movement for broadcast/transpose/slice/pad/concatenate.  Both
//!   tiers and all dot/conv variants follow one pinned 8-lane
//!   accumulation contract (see the kernels module docs), so tier and
//!   plan choice are bit-invisible.
//! * [`exec`] — the executor: runs a [`program::Program`] over a reusable
//!   per-call buffer arena (slot-indexed, sized once at first call, f32
//!   slots 32-byte aligned for straddle-free lane loads), so steady-state
//!   training steps do near-zero allocation.  `Literal` arguments are
//!   borrowed, never cloned.
//! * [`fmath`] — deterministic `f32` math kernels (exp, log1p, logistic,
//!   tanh, ...) computed via fixed `f64` polynomial evaluation, so compiled
//!   results are bit-identical across platforms and libm versions (the
//!   golden-record byte gate relies on this).
//! * [`reference`] — the pre-PR tree-walk evaluator, retained verbatim as
//!   the differential-testing baseline and the `perf_interp` bench's
//!   speedup reference.  It still uses the platform libm; the differential
//!   suite compares the two paths under a 1e-6 tolerance.
//!
//! Numerics: elementwise math is performed in `f32` with a fixed
//! per-element order; dot and grouped-Add reduce accumulate through the
//! pinned 8-lane contract (lane `k % 8`, ascending within lane, pairwise
//! fold — the [`kernels`] module docs spell it out), mirroring the XLA
//! CPU backend closely enough that the committed jax goldens agree to
//! ~1e-5 relative.  Results are bit-identical across runs, across engine
//! workers, across tiers and dot-plan variants, and (for the compiled
//! path) across platforms.

pub(crate) mod cost;
pub(crate) mod exec;
pub(crate) mod fmath;
pub(crate) mod kernels;
pub(crate) mod parse;
pub(crate) mod program;
pub(crate) mod reference;

pub(crate) use parse::Module;

use crate::{Literal, Result};

/// A compiled HLO module: the parsed form (kept for the reference
/// evaluation path) plus the lowered register program executed by the
/// default path.
#[derive(Debug)]
pub(crate) struct Compiled {
    module: Module,
    program: program::Program,
}

impl Compiled {
    /// Parse and lower `text` (both phases happen at compile time, so any
    /// unsupported construct fails before a train loop starts).
    pub(crate) fn compile(text: &str) -> Result<Compiled> {
        let module = Module::parse(text)?;
        let program = program::Program::compile(&module)?;
        Ok(Compiled { module, program })
    }

    /// Execute the compiled register program (the default path, at the
    /// `DIVEBATCH_INTERP_TIER` process-default tier).
    pub(crate) fn execute(&self, args: &[&Literal]) -> Result<Literal> {
        self.program.execute(args)
    }

    /// Execute at an explicit tier (bit-identical across tiers; used by
    /// the differential suite and the `perf_interp_simd` bench).
    pub(crate) fn execute_with_tier(
        &self,
        args: &[&Literal],
        tier: crate::InterpTier,
    ) -> Result<Literal> {
        self.program.execute_with_tier(args, tier)
    }

    /// Execute through the retained tree-walk reference evaluator.
    pub(crate) fn execute_reference(&self, args: &[&Literal]) -> Result<Literal> {
        reference::evaluate(&self.module, args)
    }

    /// (arenas created, buffers grown) — the bench's allocs-proxy.
    pub(crate) fn arena_stats(&self) -> (u64, u64) {
        self.program.arena_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::program::{self, Ref, Step};
    use super::*;

    /// Compile + execute through the register program, assert the
    /// reference path agrees to 1e-6, and return the decomposed outputs.
    fn eval(text: &str, args: &[&Literal]) -> Vec<Literal> {
        let compiled = Compiled::compile(text).unwrap();
        let mut root = compiled.execute(args).unwrap();
        let mut ref_root = compiled.execute_reference(args).unwrap();
        let parts = match root.decompose_tuple() {
            Ok(parts) => parts,
            Err(_) => vec![root],
        };
        let ref_parts = match ref_root.decompose_tuple() {
            Ok(parts) => parts,
            Err(_) => vec![ref_root],
        };
        assert_eq!(parts.len(), ref_parts.len());
        for (p, r) in parts.iter().zip(&ref_parts) {
            if let (Ok(pv), Ok(rv)) = (p.to_vec::<f32>(), r.to_vec::<f32>()) {
                for (a, b) in pv.iter().zip(&rv) {
                    assert!(
                        (a - b).abs() as f64 <= 1e-6 * (1.0 + b.abs() as f64),
                        "compiled {a} vs reference {b}"
                    );
                }
            }
            if let (Ok(pv), Ok(rv)) = (p.to_vec::<i32>(), r.to_vec::<i32>()) {
                assert_eq!(pv, rv);
            }
        }
        parts
    }

    #[test]
    fn matvec_bias_roundtrip() {
        // y = x @ w + b over f32[2,3] x f32[3], b broadcast from w tail.
        let text = r#"
HloModule t, entry_computation_layout={(f32[4]{0}, f32[2,3]{1,0})->(f32[2])}

ENTRY main.10 {
  Arg_0.1 = f32[4]{0} parameter(0)
  Arg_1.2 = f32[2,3]{1,0} parameter(1)
  slice.3 = f32[3]{0} slice(Arg_0.1), slice={[0:3]}
  dot.4 = f32[2]{0} dot(Arg_1.2, slice.3), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  slice.5 = f32[1]{0} slice(Arg_0.1), slice={[3:4]}
  reshape.6 = f32[] reshape(slice.5)
  broadcast.7 = f32[2]{0} broadcast(reshape.6), dimensions={}
  add.8 = f32[2]{0} add(dot.4, broadcast.7)
  ROOT tuple.9 = (f32[2]{0}) tuple(add.8)
}
"#;
        let params = Literal::vec1(&[1.0f32, 2.0, 3.0, 0.5]);
        let x = Literal::vec1(&[1.0f32, 0.0, -1.0, 2.0, 2.0, 2.0])
            .reshape(&[2, 3])
            .unwrap();
        let out = eval(text, &[&params, &x]);
        assert_eq!(out.len(), 1);
        // Row 0: 1*1 + 0*2 + -1*3 + 0.5 = -1.5; row 1: 2+4+6+0.5 = 12.5.
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![-1.5, 12.5]);
    }

    #[test]
    fn reduce_rows_and_columns() {
        let text = r#"
HloModule t

region_0.1 {
  Arg_0.2 = f32[] parameter(0)
  Arg_1.3 = f32[] parameter(1)
  ROOT add.4 = f32[] add(Arg_0.2, Arg_1.3)
}

ENTRY main.10 {
  Arg_0.1 = f32[2,3]{1,0} parameter(0)
  constant.2 = f32[] constant(0)
  reduce.3 = f32[2]{0} reduce(Arg_0.1, constant.2), dimensions={1}, to_apply=region_0.1
  reduce.4 = f32[3]{0} reduce(Arg_0.1, constant.2), dimensions={0}, to_apply=region_0.1
  reduce.5 = f32[] reduce(Arg_0.1, constant.2), dimensions={0,1}, to_apply=region_0.1
  ROOT tuple.6 = (f32[2]{0}, f32[3]{0}, f32[]) tuple(reduce.3, reduce.4, reduce.5)
}
"#;
        let x = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0])
            .reshape(&[2, 3])
            .unwrap();
        let out = eval(text, &[&x]);
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![6.0, 15.0]);
        assert_eq!(out[1].to_vec::<f32>().unwrap(), vec![5.0, 7.0, 9.0]);
        assert_eq!(out[2].get_first_element::<f32>().unwrap(), 21.0);
    }

    #[test]
    fn multi_op_reduce_region_compiles_to_register_form() {
        // region(acc, x) = acc + (2*x + x*x): outside the one-op fast
        // path, so it exercises the compiled scalar register program
        // (satellite: no per-element region re-evaluation).
        let text = r#"
HloModule t

region_0.1 {
  Arg_0.2 = f32[] parameter(0)
  Arg_1.3 = f32[] parameter(1)
  constant.4 = f32[] constant(2)
  multiply.5 = f32[] multiply(constant.4, Arg_1.3)
  multiply.6 = f32[] multiply(Arg_1.3, Arg_1.3)
  add.7 = f32[] add(multiply.5, multiply.6)
  ROOT add.8 = f32[] add(Arg_0.2, add.7)
}

ENTRY main.5 {
  Arg_0.1 = f32[2,3]{1,0} parameter(0)
  constant.2 = f32[] constant(1)
  reduce.3 = f32[2]{0} reduce(Arg_0.1, constant.2), dimensions={1}, to_apply=region_0.1
  ROOT tuple.4 = (f32[2]{0}) tuple(reduce.3)
}
"#;
        let compiled = Compiled::compile(text).unwrap();
        // White-box: the reduce step must carry a compiled region program.
        let has_program_region = compiled.program.steps.iter().any(|s| {
            matches!(
                s,
                Step::Reduce(p) if matches!(p.region, program::RegionFn::Program(_))
            )
        });
        assert!(has_program_region, "multi-op region not register-compiled");
        let x = Literal::vec1(&[1.0f32, 2.0, 3.0, -1.0, 0.5, 2.0])
            .reshape(&[2, 3])
            .unwrap();
        let out = eval(text, &[&x]);
        // Row 0: 1 + (2+1) + (4+4) + (6+9) = 27; row 1: 1 + (-2+1) + (1+0.25) + (4+4) = 9.25.
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![27.0, 9.25]);
    }

    #[test]
    fn compare_select_convert_pad() {
        let text = r#"
HloModule t

ENTRY main.12 {
  Arg_0.1 = f32[4]{0} parameter(0)
  constant.2 = f32[] constant(0)
  broadcast.3 = f32[4]{0} broadcast(constant.2), dimensions={}
  compare.4 = pred[4]{0} compare(Arg_0.1, broadcast.3), direction=GT
  convert.5 = f32[4]{0} convert(compare.4)
  negate.6 = f32[4]{0} negate(Arg_0.1)
  select.7 = f32[4]{0} select(compare.4, Arg_0.1, negate.6)
  pad.8 = f32[6]{0} pad(select.7, constant.2), padding=1_1
  ROOT tuple.9 = (f32[4]{0}, f32[6]{0}) tuple(convert.5, pad.8)
}
"#;
        let x = Literal::vec1(&[1.5f32, -2.0, 0.0, 3.0]);
        let out = eval(text, &[&x]);
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![1.0, 0.0, 0.0, 1.0]);
        // select implements |x|; pad adds one zero each side.
        assert_eq!(
            out[1].to_vec::<f32>().unwrap(),
            vec![0.0, 1.5, 2.0, 0.0, 3.0, 0.0]
        );
    }

    #[test]
    fn transpose_concatenate_iota() {
        let text = r#"
HloModule t

ENTRY main.7 {
  Arg_0.1 = f32[2,3]{1,0} parameter(0)
  transpose.2 = f32[3,2]{1,0} transpose(Arg_0.1), dimensions={1,0}
  reshape.3 = f32[6]{0} reshape(transpose.2)
  iota.4 = f32[2]{0} iota(), iota_dimension=0
  concatenate.5 = f32[8]{0} concatenate(reshape.3, iota.4), dimensions={0}
  ROOT tuple.6 = (f32[8]{0}) tuple(concatenate.5)
}
"#;
        let x = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0])
            .reshape(&[2, 3])
            .unwrap();
        let out = eval(text, &[&x]);
        assert_eq!(
            out[0].to_vec::<f32>().unwrap(),
            vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0, 0.0, 1.0]
        );
    }

    #[test]
    fn math_unaries_match_deterministic_kernels() {
        let text = r#"
HloModule t

ENTRY main.8 {
  Arg_0.1 = f32[3]{0} parameter(0)
  exponential.2 = f32[3]{0} exponential(Arg_0.1)
  log-plus-one.3 = f32[3]{0} log-plus-one(Arg_0.1)
  logistic.4 = f32[3]{0} logistic(Arg_0.1)
  abs.5 = f32[3]{0} abs(Arg_0.1)
  ROOT tuple.6 = (f32[3]{0}, f32[3]{0}, f32[3]{0}, f32[3]{0}) tuple(exponential.2, log-plus-one.3, logistic.4, abs.5)
}
"#;
        let xs = [0.5f32, -1.25, 2.0];
        let out = eval(text, &[&Literal::vec1(&xs)]);
        let exp = out[0].to_vec::<f32>().unwrap();
        let l1p = out[1].to_vec::<f32>().unwrap();
        let sig = out[2].to_vec::<f32>().unwrap();
        let abs = out[3].to_vec::<f32>().unwrap();
        for (i, &x) in xs.iter().enumerate() {
            // The compiled path uses the deterministic fmath kernels:
            // equal to the platform libm within ~1 ulp, and exactly equal
            // to fmath by construction.
            assert_eq!(exp[i], fmath::exp(x));
            assert!((exp[i] as f64 - (x as f64).exp()).abs() < 1e-6 * (x as f64).exp());
            assert_eq!(l1p[i], fmath::ln_1p(x));
            assert_eq!(sig[i], fmath::logistic(x));
            assert!((sig[i] as f64 - 1.0 / (1.0 + (-x as f64).exp())).abs() < 1e-6);
            assert_eq!(abs[i], x.abs());
        }
    }

    #[test]
    fn deep_elementwise_chain_fuses_and_matches_reference() {
        // A single-consumer chain long enough to fuse several ops; the
        // shared broadcast (two consumers) must stay materialized.
        let text = r#"
HloModule t

ENTRY main.12 {
  Arg_0.1 = f32[5]{0} parameter(0)
  constant.2 = f32[] constant(1)
  broadcast.3 = f32[5]{0} broadcast(constant.2), dimensions={}
  negate.4 = f32[5]{0} negate(Arg_0.1)
  exponential.5 = f32[5]{0} exponential(negate.4)
  add.6 = f32[5]{0} add(exponential.5, broadcast.3)
  divide.7 = f32[5]{0} divide(broadcast.3, add.6)
  subtract.8 = f32[5]{0} subtract(divide.7, Arg_0.1)
  multiply.9 = f32[5]{0} multiply(subtract.8, subtract.8)
  ROOT tuple.10 = (f32[5]{0}) tuple(multiply.9)
}
"#;
        let compiled = Compiled::compile(text).unwrap();
        // The whole chain collapses into one fused step (the broadcast is
        // a gather step feeding it).
        let fused_steps = compiled
            .program
            .steps
            .iter()
            .filter(|s| matches!(s, Step::Fused(_)))
            .count();
        let max_ops = compiled
            .program
            .steps
            .iter()
            .filter_map(|s| match s {
                Step::Fused(f) => Some(f.ops.len()),
                _ => None,
            })
            .max()
            .unwrap();
        assert_eq!(fused_steps, 1, "chain should fuse into one loop");
        assert!(max_ops >= 6, "expected a deep fused group, got {max_ops}");
        let x = Literal::vec1(&[0.3f32, -0.7, 2.0, 0.0, -3.5]);
        let out = eval(text, &[&x]);
        for (o, &xv) in out[0].to_vec::<f32>().unwrap().iter().zip(&[
            0.3f32, -0.7, 2.0, 0.0, -3.5,
        ]) {
            let sig = 1.0 / (1.0 + (-xv as f64).exp());
            let want = (sig - xv as f64) * (sig - xv as f64);
            assert!((*o as f64 - want).abs() < 1e-5, "{o} vs {want}");
        }
    }

    #[test]
    fn constants_including_inf_and_arrays() {
        let text = r#"
HloModule t

ENTRY main.5 {
  constant.1 = f32[] constant(inf)
  constant.2 = f32[3]{0} constant({1, -2.5, 3e2})
  constant.3 = s32[2]{0} constant({7, -9})
  ROOT tuple.4 = (f32[], f32[3]{0}, s32[2]{0}) tuple(constant.1, constant.2, constant.3)
}
"#;
        let out = eval(text, &[]);
        assert_eq!(out[0].get_first_element::<f32>().unwrap(), f32::INFINITY);
        assert_eq!(out[1].to_vec::<f32>().unwrap(), vec![1.0, -2.5, 300.0]);
        assert_eq!(out[2].to_vec::<i32>().unwrap(), vec![7, -9]);
    }

    #[test]
    fn argument_validation_names_parameter_and_shapes() {
        let text = r#"
HloModule t

ENTRY main.3 {
  Arg_0.1 = f32[4]{0} parameter(0)
  ROOT tuple.2 = (f32[4]{0}) tuple(Arg_0.1)
}
"#;
        let compiled = Compiled::compile(text).unwrap();
        let bad = Literal::vec1(&[1.0f32, 2.0]);
        let e = compiled.execute(&[&bad]).unwrap_err().to_string();
        assert!(e.contains("Arg_0.1") && e.contains("f32[4]"), "{e}");
        let e = compiled.execute(&[]).unwrap_err().to_string();
        assert!(e.contains("1 parameters"), "{e}");
        // The reference path validates identically.
        let e = compiled.execute_reference(&[&bad]).unwrap_err().to_string();
        assert!(e.contains("Arg_0.1"), "{e}");
    }

    #[test]
    fn unsupported_ops_rejected_at_parse_time() {
        let text = r#"
HloModule t

ENTRY main.3 {
  Arg_0.1 = f32[4]{0} parameter(0)
  ROOT custom-call.2 = f32[4]{0} custom-call(Arg_0.1), custom_call_target="foo"
}
"#;
        // Rejected at parse ("compile") time, naming the opcode, so a bad
        // artifact fails before any training loop starts.
        let e = Compiled::compile(text).unwrap_err().to_string();
        assert!(e.contains("custom-call"), "{e}");
    }

    #[test]
    fn canonical_text_with_typed_operands_parses() {
        // The canonical HLO printer prefixes operands with types and '%'.
        let text = r#"
HloModule t

ENTRY %main.4 (Arg_0.1: f32[2]) -> (f32[2]) {
  %Arg_0.1 = f32[2]{0} parameter(0)
  %add.2 = f32[2]{0} add(f32[2]{0} %Arg_0.1, f32[2]{0} %Arg_0.1)
  ROOT %tuple.3 = (f32[2]{0}) tuple(f32[2]{0} %add.2)
}
"#;
        let out = eval(text, &[&Literal::vec1(&[1.0f32, -3.0])]);
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![2.0, -6.0]);
    }

    #[test]
    fn arena_is_reused_across_calls() {
        let text = r#"
HloModule t

ENTRY main.4 {
  Arg_0.1 = f32[3]{0} parameter(0)
  add.2 = f32[3]{0} add(Arg_0.1, Arg_0.1)
  ROOT tuple.3 = (f32[3]{0}) tuple(add.2)
}
"#;
        let compiled = Compiled::compile(text).unwrap();
        let x = Literal::vec1(&[1.0f32, 2.0, 3.0]);
        for _ in 0..100 {
            compiled.execute(&[&x]).unwrap();
        }
        let (created, grown) = compiled.arena_stats();
        assert_eq!(created, 1, "serial calls must reuse one arena");
        assert_eq!(grown, 0, "slots are sized at compile time");
    }

    /// Last-use analysis correctness: walking a compiled program's steps,
    /// a slot assigned to a new value must not still be live for an
    /// earlier value (the arena must never alias live slots).
    fn assert_alias_free(prog: &program::Program) {
        // Reconstruct per-step writes/reads from the plan structs.
        let step_writes = |s: &Step| -> Vec<u32> {
            match s {
                Step::Fused(f) => vec![f.out],
                Step::IntEw { out, .. }
                | Step::PredEw { out, .. }
                | Step::Compare { out, .. }
                | Step::Select { out, .. }
                | Step::Convert { out, .. }
                | Step::Gather { out, .. }
                | Step::Pad { out, .. }
                | Step::Concat { out, .. }
                | Step::DynSlice { out, .. }
                | Step::DynUpdate { out, .. } => vec![*out],
                Step::Dot(p) => vec![p.out],
                Step::Reduce(p) => vec![p.out],
                Step::Conv(p) => {
                    let mut v: Vec<u32> = p.scratch.map(|s| s.to_vec()).unwrap_or_default();
                    v.push(p.out);
                    v
                }
                Step::Call { outs, .. } | Step::While { outs, .. } => outs.clone(),
            }
        };
        let step_reads = |s: &Step| -> Vec<u32> {
            fn slot(r: Ref) -> Option<u32> {
                match r {
                    Ref::Slot(s) => Some(s),
                    _ => None,
                }
            }
            let refs: Vec<Ref> = match s {
                Step::Fused(f) => f.inputs.clone(),
                Step::IntEw { a, b, .. } | Step::PredEw { a, b, .. } => {
                    let mut v = vec![*a];
                    v.extend(*b);
                    v
                }
                Step::Compare { a, b, .. } => vec![*a, *b],
                Step::Select { p, t, f, .. } => vec![*p, *t, *f],
                Step::Convert { a, .. } => vec![*a],
                Step::Gather { src, .. } => vec![*src],
                Step::Pad { src, fill, .. } => vec![*src, *fill],
                Step::Concat { parts, .. } => parts.iter().map(|(r, _)| *r).collect(),
                Step::Dot(p) => vec![p.lhs, p.rhs],
                Step::Reduce(p) => vec![p.data, p.init],
                Step::Conv(p) => vec![p.lhs, p.rhs],
                Step::DynSlice { src, starts, .. } => {
                    let mut v = vec![*src];
                    v.extend(starts);
                    v
                }
                Step::DynUpdate {
                    src, upd, starts, ..
                } => {
                    let mut v = vec![*src, *upd];
                    v.extend(starts);
                    v
                }
                Step::Call { args, .. } => args.clone(),
                Step::While { init, .. } => init.clone(),
            };
            refs.into_iter().filter_map(slot).collect()
        };

        // Liveness check: value v born at step i in slot s is live until
        // its last read (or program end if it is an output); no other step
        // in that span may write slot s.
        let n_steps = prog.steps.len();
        let out_slots: Vec<u32> = prog
            .outputs
            .iter()
            .filter_map(|o| match o.r {
                Ref::Slot(s) => Some(s),
                _ => None,
            })
            .collect();
        for i in 0..n_steps {
            for &s in &step_writes(&prog.steps[i]) {
                let mut last = i;
                for (j, sj) in prog.steps.iter().enumerate().skip(i + 1) {
                    if step_reads(sj).contains(&s) {
                        last = j;
                    }
                }
                if out_slots.contains(&s) {
                    last = n_steps - 1;
                }
                for (j, sj) in prog.steps.iter().enumerate().take(last + 1).skip(i + 1) {
                    assert!(
                        !step_writes(sj).contains(&s),
                        "step {j} overwrites slot {s} while step {i}'s value is still live"
                    );
                }
            }
        }
    }

    #[test]
    fn slot_reuse_is_alias_free() {
        let text = r#"
HloModule t

region_0.1 {
  Arg_0.2 = f32[] parameter(0)
  Arg_1.3 = f32[] parameter(1)
  ROOT add.4 = f32[] add(Arg_0.2, Arg_1.3)
}

ENTRY main.20 {
  Arg_0.1 = f32[4,4]{1,0} parameter(0)
  Arg_1.2 = f32[4]{0} parameter(1)
  dot.3 = f32[4]{0} dot(Arg_0.1, Arg_1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  constant.4 = f32[] constant(0)
  broadcast.5 = f32[4]{0} broadcast(constant.4), dimensions={}
  maximum.6 = f32[4]{0} maximum(dot.3, broadcast.5)
  exponential.7 = f32[4]{0} exponential(maximum.6)
  multiply.8 = f32[4,4]{1,0} multiply(Arg_0.1, Arg_0.1)
  reduce.9 = f32[4]{0} reduce(multiply.8, constant.4), dimensions={1}, to_apply=region_0.1
  add.10 = f32[4]{0} add(exponential.7, reduce.9)
  transpose.11 = f32[4,4]{1,0} transpose(multiply.8), dimensions={1,0}
  reduce.12 = f32[4]{0} reduce(transpose.11, constant.4), dimensions={0}, to_apply=region_0.1
  subtract.13 = f32[4]{0} subtract(add.10, reduce.12)
  ROOT tuple.14 = (f32[4]{0}, f32[4]{0}) tuple(subtract.13, add.10)
}
"#;
        let compiled = Compiled::compile(text).unwrap();
        let prog = &compiled.program;
        assert_alias_free(prog);

        // And the program must actually reuse slots (fewer slots than
        // materialized steps), otherwise the arena is doing nothing.
        assert!(
            prog.slots.len() < prog.steps.len(),
            "no slot reuse: {} slots for {} steps",
            prog.slots.len(),
            prog.steps.len()
        );

        // Finally: numerics agree with the reference evaluator.
        let a = Literal::vec1(&(0..16).map(|i| (i as f32) * 0.25 - 2.0).collect::<Vec<_>>())
            .reshape(&[4, 4])
            .unwrap();
        let b = Literal::vec1(&[0.5f32, -1.0, 2.0, 0.0]);
        eval(text, &[&a, &b]);
    }

    /// Execute at both tiers and require byte-identical outputs (the
    /// pinned lanes contract makes tier choice bit-invisible).
    fn assert_tiers_bitwise(text: &str, args: &[&Literal]) {
        let compiled = Compiled::compile(text).unwrap();
        let mut simd = compiled
            .execute_with_tier(args, crate::InterpTier::Simd)
            .unwrap();
        let mut scalar = compiled
            .execute_with_tier(args, crate::InterpTier::Scalar)
            .unwrap();
        let sp = match simd.decompose_tuple() {
            Ok(parts) => parts,
            Err(_) => vec![simd],
        };
        let cp = match scalar.decompose_tuple() {
            Ok(parts) => parts,
            Err(_) => vec![scalar],
        };
        assert_eq!(sp.len(), cp.len());
        for (p, q) in sp.iter().zip(&cp) {
            if let (Ok(pv), Ok(qv)) = (p.to_vec::<f32>(), q.to_vec::<f32>()) {
                assert_eq!(
                    pv.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    qv.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "SIMD and scalar tiers diverged"
                );
            }
            if let (Ok(pv), Ok(qv)) = (p.to_vec::<i32>(), q.to_vec::<i32>()) {
                assert_eq!(pv, qv);
            }
        }
    }

    #[test]
    fn tiers_agree_bitwise_on_odd_shapes() {
        // k=11 and length-13 vectors exercise every scalar tail; the
        // reduce shapes cover grouped (trailing), flat (leading), and
        // full-to-scalar layouts.
        let text = r#"
HloModule t

region_0.1 {
  Arg_0.2 = f32[] parameter(0)
  Arg_1.3 = f32[] parameter(1)
  ROOT add.4 = f32[] add(Arg_0.2, Arg_1.3)
}

ENTRY main.14 {
  Arg_0.1 = f32[3,11]{1,0} parameter(0)
  Arg_1.2 = f32[11]{0} parameter(1)
  Arg_2.3 = f32[3,13]{1,0} parameter(2)
  dot.4 = f32[3]{0} dot(Arg_0.1, Arg_1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  exponential.5 = f32[3]{0} exponential(dot.4)
  constant.6 = f32[] constant(0.5)
  reduce.7 = f32[] reduce(exponential.5, constant.6), dimensions={0}, to_apply=region_0.1
  reduce.8 = f32[3]{0} reduce(Arg_2.3, constant.6), dimensions={1}, to_apply=region_0.1
  reduce.9 = f32[13]{0} reduce(Arg_2.3, constant.6), dimensions={0}, to_apply=region_0.1
  dot.10 = f32[11,13]{1,0} dot(Arg_0.1, Arg_2.3), lhs_contracting_dims={0}, rhs_contracting_dims={0}
  ROOT tuple.11 = (f32[3]{0}, f32[], f32[3]{0}, f32[13]{0}, f32[11,13]{1,0}) tuple(dot.4, reduce.7, reduce.8, reduce.9, dot.10)
}
"#;
        let a = Literal::vec1(
            &(0..33)
                .map(|i| ((i * 37 % 17) as f32) * 0.21 - 1.7)
                .collect::<Vec<f32>>(),
        )
        .reshape(&[3, 11])
        .unwrap();
        let b = Literal::vec1(
            &(0..11)
                .map(|i| ((i * 29 % 13) as f32) * 0.33 - 2.1)
                .collect::<Vec<f32>>(),
        );
        let c = Literal::vec1(
            &(0..39)
                .map(|i| ((i * 53 % 19) as f32) * 0.17 - 1.3)
                .collect::<Vec<f32>>(),
        )
        .reshape(&[3, 13])
        .unwrap();
        assert_tiers_bitwise(text, &[&a, &b, &c]);
        // And both stay within the differential tolerance of the
        // tree-walk reference.
        eval(text, &[&a, &b, &c]);
    }

    #[test]
    fn cost_model_selects_expected_plans() {
        use super::cost::{DotAlgo, ReduceAlgo};
        let text = r#"
HloModule t

region_0.1 {
  Arg_0.2 = f32[] parameter(0)
  Arg_1.3 = f32[] parameter(1)
  ROOT add.4 = f32[] add(Arg_0.2, Arg_1.3)
}

ENTRY main.12 {
  Arg_0.1 = f32[4,6]{1,0} parameter(0)
  Arg_1.2 = f32[6,5]{1,0} parameter(1)
  Arg_2.3 = f32[5,6]{1,0} parameter(2)
  Arg_3.4 = f32[6]{0} parameter(3)
  dot.5 = f32[4,5]{1,0} dot(Arg_0.1, Arg_1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  dot.6 = f32[4,5]{1,0} dot(Arg_0.1, Arg_2.3), lhs_contracting_dims={1}, rhs_contracting_dims={1}
  dot.7 = f32[4]{0} dot(Arg_0.1, Arg_3.4), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  constant.8 = f32[] constant(0)
  reduce.9 = f32[4]{0} reduce(dot.5, constant.8), dimensions={1}, to_apply=region_0.1
  reduce.10 = f32[5]{0} reduce(dot.6, constant.8), dimensions={0}, to_apply=region_0.1
  ROOT tuple.11 = (f32[4]{0}, f32[4]{0}, f32[5]{0}) tuple(dot.7, reduce.9, reduce.10)
}
"#;
        let compiled = Compiled::compile(text).unwrap();
        let dot_algos: Vec<DotAlgo> = compiled
            .program
            .steps
            .iter()
            .filter_map(|s| match s {
                Step::Dot(p) => Some(p.algo),
                _ => None,
            })
            .collect();
        // dot.5: rhs [6,5] contracting dim 0 -> r_kstride=5, iota columns.
        // dot.6: rhs [5,6] contracting dim 1 -> fully contiguous, n=5>=NR.
        // dot.7: rhs [6] vector -> contiguous, single column.
        assert_eq!(
            dot_algos,
            vec![DotAlgo::AxpyLanes, DotAlgo::LanesTiled, DotAlgo::LanesContig]
        );
        let reduce_algos: Vec<ReduceAlgo> = compiled
            .program
            .steps
            .iter()
            .filter_map(|s| match s {
                Step::Reduce(p) => Some(p.algo),
                _ => None,
            })
            .collect();
        // reduce.9 folds the trailing dim (grouped); reduce.10 the
        // leading dim (interleaved -> flat walk).
        assert_eq!(
            reduce_algos,
            vec![ReduceAlgo::GroupedLanes { group: 5 }, ReduceAlgo::Flat]
        );
        // Numerics still match the reference on this module.
        let a = Literal::vec1(&(0..24).map(|i| i as f32 * 0.1).collect::<Vec<f32>>())
            .reshape(&[4, 6])
            .unwrap();
        let b = Literal::vec1(&(0..30).map(|i| 1.0 - i as f32 * 0.05).collect::<Vec<f32>>())
            .reshape(&[6, 5])
            .unwrap();
        let c = Literal::vec1(&(0..30).map(|i| (i as f32 * 0.07) - 0.9).collect::<Vec<f32>>())
            .reshape(&[5, 6])
            .unwrap();
        let d = Literal::vec1(&(0..6).map(|i| i as f32 * 0.4 - 1.0).collect::<Vec<f32>>());
        assert_tiers_bitwise(text, &[&a, &b, &c, &d]);
        eval(text, &[&a, &b, &c, &d]);
    }

    #[test]
    fn pred_entry_parameters_rejected_at_compile_time() {
        let text = r#"
HloModule t

ENTRY main.3 {
  Arg_0.1 = pred[2]{0} parameter(0)
  ROOT tuple.2 = (pred[2]{0}) tuple(Arg_0.1)
}
"#;
        // The crate contract: unsupported constructs fail at compile time,
        // before a train loop starts — not with an opaque internal error
        // deep in execute.
        let e = Compiled::compile(text).unwrap_err().to_string();
        assert!(
            e.contains("pred entry parameters are not supported"),
            "{e}"
        );
        assert!(e.contains("Arg_0.1") && e.contains("main.3"), "{e}");
    }

    #[test]
    fn negative_edge_padding_crops_on_both_paths() {
        // Negative edge padding (legal HLO, produced by conv input-grad
        // lowerings) crops: pad=-1_-1 over [6] keeps the middle 4.
        let text = r#"
HloModule t

ENTRY main.5 {
  Arg_0.1 = f32[6]{0} parameter(0)
  constant.2 = f32[] constant(0)
  pad.3 = f32[4]{0} pad(Arg_0.1, constant.2), padding=-1_-1
  mixed.4 = f32[7]{0} pad(Arg_0.1, constant.2), padding=2_-1
  ROOT tuple.5 = (f32[4]{0}, f32[7]{0}) tuple(pad.3, mixed.4)
}
"#;
        let x = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let out = eval(text, &[&x]);
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![2.0, 3.0, 4.0, 5.0]);
        // Mixed padding: 2 zeros in front, the last element cropped.
        assert_eq!(
            out[1].to_vec::<f32>().unwrap(),
            vec![0.0, 0.0, 1.0, 2.0, 3.0, 4.0, 5.0]
        );
        assert_tiers_bitwise(text, &[&x]);
    }

    #[test]
    fn while_loop_matches_reference_and_tiers() {
        // Three iterations of (i += 1, v *= 2); the initial argument is
        // also consumed after the loop, so loop-carried slots must not
        // alias still-live values.
        let text = r#"
HloModule t

cond_c.1 {
  arg_tuple.2 = (s32[], f32[2]{0}) parameter(0)
  get-tuple-element.3 = s32[] get-tuple-element(arg_tuple.2), index=0
  constant.4 = s32[] constant(3)
  ROOT compare.5 = pred[] compare(get-tuple-element.3, constant.4), direction=LT
}

body_c.6 {
  arg_tuple.7 = (s32[], f32[2]{0}) parameter(0)
  get-tuple-element.8 = s32[] get-tuple-element(arg_tuple.7), index=0
  constant.9 = s32[] constant(1)
  add.10 = s32[] add(get-tuple-element.8, constant.9)
  get-tuple-element.11 = f32[2]{0} get-tuple-element(arg_tuple.7), index=1
  add.12 = f32[2]{0} add(get-tuple-element.11, get-tuple-element.11)
  ROOT tuple.13 = (s32[], f32[2]{0}) tuple(add.10, add.12)
}

ENTRY main.20 {
  Arg_0.1 = f32[2]{0} parameter(0)
  constant.2 = s32[] constant(0)
  tuple.3 = (s32[], f32[2]{0}) tuple(constant.2, Arg_0.1)
  while.4 = (s32[], f32[2]{0}) while(tuple.3), condition=cond_c.1, body=body_c.6
  get-tuple-element.5 = s32[] get-tuple-element(while.4), index=0
  get-tuple-element.6 = f32[2]{0} get-tuple-element(while.4), index=1
  add.7 = f32[2]{0} add(get-tuple-element.6, Arg_0.1)
  ROOT tuple.8 = (s32[], f32[2]{0}, f32[2]{0}) tuple(get-tuple-element.5, get-tuple-element.6, add.7)
}
"#;
        let compiled = Compiled::compile(text).unwrap();
        assert!(
            compiled
                .program
                .steps
                .iter()
                .any(|s| matches!(s, Step::While { .. })),
            "while must lower to a compiled loop step"
        );
        assert_alias_free(&compiled.program);
        let x = Literal::vec1(&[1.5f32, -2.0]);
        let out = eval(text, &[&x]);
        assert_eq!(out[0].get_first_element::<i32>().unwrap(), 3);
        assert_eq!(out[1].to_vec::<f32>().unwrap(), vec![12.0, -16.0]);
        assert_eq!(out[2].to_vec::<f32>().unwrap(), vec![13.5, -18.0]);
        assert_tiers_bitwise(text, &[&x]);
    }

    #[test]
    fn while_zero_trip_returns_initial_state() {
        let text = r#"
HloModule t

cond_c.1 {
  arg_tuple.2 = (s32[], f32[2]{0}) parameter(0)
  get-tuple-element.3 = s32[] get-tuple-element(arg_tuple.2), index=0
  constant.4 = s32[] constant(3)
  ROOT compare.5 = pred[] compare(get-tuple-element.3, constant.4), direction=LT
}

body_c.6 {
  arg_tuple.7 = (s32[], f32[2]{0}) parameter(0)
  get-tuple-element.8 = s32[] get-tuple-element(arg_tuple.7), index=0
  constant.9 = s32[] constant(1)
  add.10 = s32[] add(get-tuple-element.8, constant.9)
  get-tuple-element.11 = f32[2]{0} get-tuple-element(arg_tuple.7), index=1
  add.12 = f32[2]{0} add(get-tuple-element.11, get-tuple-element.11)
  ROOT tuple.13 = (s32[], f32[2]{0}) tuple(add.10, add.12)
}

ENTRY main.20 {
  Arg_0.1 = f32[2]{0} parameter(0)
  constant.2 = s32[] constant(7)
  tuple.3 = (s32[], f32[2]{0}) tuple(constant.2, Arg_0.1)
  while.4 = (s32[], f32[2]{0}) while(tuple.3), condition=cond_c.1, body=body_c.6
  get-tuple-element.5 = s32[] get-tuple-element(while.4), index=0
  get-tuple-element.6 = f32[2]{0} get-tuple-element(while.4), index=1
  ROOT tuple.7 = (s32[], f32[2]{0}) tuple(get-tuple-element.5, get-tuple-element.6)
}
"#;
        // 7 < 3 is false on entry: zero iterations, state passes through.
        let x = Literal::vec1(&[0.25f32, 4.0]);
        let out = eval(text, &[&x]);
        assert_eq!(out[0].get_first_element::<i32>().unwrap(), 7);
        assert_eq!(out[1].to_vec::<f32>().unwrap(), vec![0.25, 4.0]);
        assert_tiers_bitwise(text, &[&x]);
    }

    #[test]
    fn while_non_pred_condition_rejected_at_compile_time() {
        let text = r#"
HloModule t

cond_c.1 {
  arg_tuple.2 = (s32[], f32[2]{0}) parameter(0)
  ROOT get-tuple-element.3 = s32[] get-tuple-element(arg_tuple.2), index=0
}

body_c.4 {
  arg_tuple.5 = (s32[], f32[2]{0}) parameter(0)
  get-tuple-element.6 = s32[] get-tuple-element(arg_tuple.5), index=0
  get-tuple-element.7 = f32[2]{0} get-tuple-element(arg_tuple.5), index=1
  ROOT tuple.8 = (s32[], f32[2]{0}) tuple(get-tuple-element.6, get-tuple-element.7)
}

ENTRY main.10 {
  Arg_0.1 = f32[2]{0} parameter(0)
  constant.2 = s32[] constant(0)
  tuple.3 = (s32[], f32[2]{0}) tuple(constant.2, Arg_0.1)
  while.4 = (s32[], f32[2]{0}) while(tuple.3), condition=cond_c.1, body=body_c.4
  ROOT get-tuple-element.5 = f32[2]{0} get-tuple-element(while.4), index=1
}
"#;
        let e = Compiled::compile(text).unwrap_err().to_string();
        assert!(e.contains("must return a scalar pred"), "{e}");
        assert!(e.contains("cond_c.1"), "{e}");
    }

    #[test]
    fn conv_basic_matches_reference_on_both_tiers() {
        // The model zoo's forward shape: 3x3 window, pad 1, channels not a
        // multiple of 8 (ci=3), NHWC / HWIO dim labels.
        let text = r#"
HloModule t

ENTRY main.4 {
  Arg_0.1 = f32[1,4,4,3]{3,2,1,0} parameter(0)
  Arg_1.2 = f32[3,3,3,5]{3,2,1,0} parameter(1)
  convolution.3 = f32[1,4,4,5]{3,2,1,0} convolution(Arg_0.1, Arg_1.2), window={size=3x3 pad=1_1x1_1}, dim_labels=b01f_01io->b01f, feature_group_count=1
  ROOT tuple.4 = (f32[1,4,4,5]{3,2,1,0}) tuple(convolution.3)
}
"#;
        let compiled = Compiled::compile(text).unwrap();
        assert!(
            compiled
                .program
                .steps
                .iter()
                .any(|s| matches!(s, Step::Conv(_))),
            "convolution must lower to a conv step"
        );
        assert_alias_free(&compiled.program);
        let x = Literal::vec1(
            &(0..48)
                .map(|i| ((i * 31 % 23) as f32) * 0.13 - 1.4)
                .collect::<Vec<f32>>(),
        )
        .reshape(&[1, 4, 4, 3])
        .unwrap();
        let w = Literal::vec1(
            &(0..135)
                .map(|i| ((i * 17 % 29) as f32) * 0.09 - 1.2)
                .collect::<Vec<f32>>(),
        )
        .reshape(&[3, 3, 3, 5])
        .unwrap();
        eval(text, &[&x, &w]);
        assert_tiers_bitwise(text, &[&x, &w]);
    }

    #[test]
    fn conv_stride_asymmetric_padding_and_groups() {
        // Odd shapes: stride 2 with asymmetric padding (0_1 x 1_0), plus a
        // grouped conv fed by an explicitly reversed kernel (the zoo's
        // input-grad idiom) with feature_group_count=2.
        let text = r#"
HloModule t

ENTRY main.8 {
  Arg_0.1 = f32[1,5,5,3]{3,2,1,0} parameter(0)
  Arg_1.2 = f32[3,3,3,4]{3,2,1,0} parameter(1)
  Arg_2.3 = f32[1,4,4,4]{3,2,1,0} parameter(2)
  Arg_3.4 = f32[3,3,2,6]{3,2,1,0} parameter(3)
  convolution.5 = f32[1,2,2,4]{3,2,1,0} convolution(Arg_0.1, Arg_1.2), window={size=3x3 stride=2x2 pad=0_1x1_0}, dim_labels=b01f_01io->b01f, feature_group_count=1
  reverse.6 = f32[3,3,2,6]{3,2,1,0} reverse(Arg_3.4), dimensions={0,1}
  convolution.7 = f32[1,4,4,6]{3,2,1,0} convolution(Arg_2.3, reverse.6), window={size=3x3 pad=1_1x1_1}, dim_labels=b01f_01io->b01f, feature_group_count=2
  ROOT tuple.8 = (f32[1,2,2,4]{3,2,1,0}, f32[1,4,4,6]{3,2,1,0}) tuple(convolution.5, convolution.7)
}
"#;
        let mk = |n: usize, mul: usize, md: usize, scale: f32, off: f32| {
            Literal::vec1(
                &(0..n)
                    .map(|i| ((i * mul % md) as f32) * scale - off)
                    .collect::<Vec<f32>>(),
            )
        };
        let a = mk(75, 41, 31, 0.11, 1.6).reshape(&[1, 5, 5, 3]).unwrap();
        let b = mk(108, 23, 19, 0.15, 1.1).reshape(&[3, 3, 3, 4]).unwrap();
        let c = mk(64, 13, 37, 0.07, 1.3).reshape(&[1, 4, 4, 4]).unwrap();
        let d = mk(108, 29, 17, 0.12, 0.9).reshape(&[3, 3, 2, 6]).unwrap();
        let compiled = Compiled::compile(text).unwrap();
        assert_alias_free(&compiled.program);
        eval(text, &[&a, &b, &c, &d]);
        assert_tiers_bitwise(text, &[&a, &b, &c, &d]);
    }

    #[test]
    fn conv_weight_grad_dim_labels() {
        // The zoo's weight-gradient conv: activations as f01b, grads as
        // i01o, output 01bf, grouped over input features.
        let text = r#"
HloModule t

ENTRY main.4 {
  Arg_0.1 = f32[4,4,4,1]{3,2,1,0} parameter(0)
  Arg_1.2 = f32[1,3,3,4]{3,2,1,0} parameter(1)
  convolution.3 = f32[4,4,1,4]{3,2,1,0} convolution(Arg_0.1, Arg_1.2), window={size=3x3 pad=1_1x1_1}, dim_labels=f01b_i01o->01bf, feature_group_count=4
  ROOT tuple.4 = (f32[4,4,1,4]{3,2,1,0}) tuple(convolution.3)
}
"#;
        let a = Literal::vec1(
            &(0..64)
                .map(|i| ((i * 19 % 27) as f32) * 0.14 - 1.7)
                .collect::<Vec<f32>>(),
        )
        .reshape(&[4, 4, 4, 1])
        .unwrap();
        let g = Literal::vec1(
            &(0..36)
                .map(|i| ((i * 11 % 13) as f32) * 0.21 - 1.0)
                .collect::<Vec<f32>>(),
        )
        .reshape(&[1, 3, 3, 4])
        .unwrap();
        eval(text, &[&a, &g]);
        assert_tiers_bitwise(text, &[&a, &g]);
    }

    #[test]
    fn conv_forced_blocked_and_im2col_agree_bitwise() {
        // `DIVEBATCH_CONV_ALGO` forces the conv strategy at compile time.
        // Both lowerings of the same module (covering plain, strided +
        // asymmetric-pad, and grouped convs) must execute to identical
        // bits on both tiers — the pinned lanes contract over the shared
        // patch K order — and the blocked lowering must reserve no conv
        // scratch slots at all.
        let text = r#"
HloModule t

ENTRY main.8 {
  Arg_0.1 = f32[1,5,5,3]{3,2,1,0} parameter(0)
  Arg_1.2 = f32[3,3,3,4]{3,2,1,0} parameter(1)
  Arg_2.3 = f32[1,4,4,4]{3,2,1,0} parameter(2)
  Arg_3.4 = f32[3,3,2,6]{3,2,1,0} parameter(3)
  convolution.5 = f32[1,2,2,4]{3,2,1,0} convolution(Arg_0.1, Arg_1.2), window={size=3x3 stride=2x2 pad=0_1x1_0}, dim_labels=b01f_01io->b01f, feature_group_count=1
  reverse.6 = f32[3,3,2,6]{3,2,1,0} reverse(Arg_3.4), dimensions={0,1}
  convolution.7 = f32[1,4,4,6]{3,2,1,0} convolution(Arg_2.3, reverse.6), window={size=3x3 pad=1_1x1_1}, dim_labels=b01f_01io->b01f, feature_group_count=2
  ROOT tuple.8 = (f32[1,2,2,4]{3,2,1,0}, f32[1,4,4,6]{3,2,1,0}) tuple(convolution.5, convolution.7)
}
"#;
        let mk = |n: usize, mul: usize, md: usize, scale: f32, off: f32| {
            Literal::vec1(
                &(0..n)
                    .map(|i| ((i * mul % md) as f32) * scale - off)
                    .collect::<Vec<f32>>(),
            )
        };
        let a = mk(75, 41, 31, 0.11, 1.6).reshape(&[1, 5, 5, 3]).unwrap();
        let b = mk(108, 23, 19, 0.15, 1.1).reshape(&[3, 3, 3, 4]).unwrap();
        let c = mk(64, 13, 37, 0.07, 1.3).reshape(&[1, 4, 4, 4]).unwrap();
        let d = mk(108, 29, 17, 0.12, 0.9).reshape(&[3, 3, 2, 6]).unwrap();

        let compile_forced = |force: &str| {
            std::env::set_var("DIVEBATCH_CONV_ALGO", force);
            let compiled = Compiled::compile(text);
            std::env::remove_var("DIVEBATCH_CONV_ALGO");
            let compiled = compiled.unwrap();
            let want = if force == "blocked" {
                cost::ConvAlgo::Blocked
            } else {
                cost::ConvAlgo::Im2col
            };
            let mut convs = 0;
            for s in &compiled.program.steps {
                if let Step::Conv(p) = s {
                    convs += 1;
                    assert_eq!(p.conv_algo, want, "forced {force}");
                    assert_eq!(p.scratch.is_none(), force == "blocked");
                }
            }
            assert_eq!(convs, 2);
            assert_alias_free(&compiled.program);
            compiled
        };
        let blocked = compile_forced("blocked");
        let im2col = compile_forced("im2col");
        // Satellite: every conv blocked -> the three shared scratch slots
        // are not reserved at all.
        assert_eq!(blocked.program.slots.len() + 3, im2col.program.slots.len());

        let mut outs: Vec<Vec<Vec<u32>>> = Vec::new();
        for compiled in [&blocked, &im2col] {
            for tier in [crate::InterpTier::Simd, crate::InterpTier::Scalar] {
                let mut root = compiled
                    .execute_with_tier(&[&a, &b, &c, &d], tier)
                    .unwrap();
                let parts = root.decompose_tuple().unwrap();
                outs.push(
                    parts
                        .iter()
                        .map(|p| {
                            p.to_vec::<f32>()
                                .unwrap()
                                .iter()
                                .map(|v| v.to_bits())
                                .collect()
                        })
                        .collect(),
                );
            }
        }
        for o in &outs[1..] {
            assert_eq!(o, &outs[0], "all (conv algo, tier) pairs must agree bitwise");
        }
    }

    #[test]
    fn dynamic_slice_update_clamp_and_calls() {
        // dynamic-slice/-update with runtime starts that clamp at both
        // ends, a dense call, and a tuple-returning call.
        let text = r#"
HloModule t

add_one.1 {
  p.2 = f32[3]{0} parameter(0)
  c.3 = f32[] constant(1)
  b.4 = f32[3]{0} broadcast(c.3), dimensions={}
  ROOT add.5 = f32[3]{0} add(p.2, b.4)
}

pair.6 {
  p.7 = f32[3]{0} parameter(0)
  negate.8 = f32[3]{0} negate(p.7)
  ROOT tuple.9 = (f32[3]{0}, f32[3]{0}) tuple(p.7, negate.8)
}

ENTRY main.20 {
  Arg_0.1 = f32[6]{0} parameter(0)
  Arg_1.2 = s32[] parameter(1)
  dynamic-slice.3 = f32[3]{0} dynamic-slice(Arg_0.1, Arg_1.2), dynamic_slice_sizes={3}
  call.4 = f32[3]{0} call(dynamic-slice.3), to_apply=add_one.1
  call.5 = (f32[3]{0}, f32[3]{0}) call(call.4), to_apply=pair.6
  get-tuple-element.6 = f32[3]{0} get-tuple-element(call.5), index=0
  get-tuple-element.7 = f32[3]{0} get-tuple-element(call.5), index=1
  dynamic-update-slice.8 = f32[6]{0} dynamic-update-slice(Arg_0.1, get-tuple-element.7, Arg_1.2)
  ROOT tuple.9 = (f32[3]{0}, f32[3]{0}, f32[6]{0}) tuple(get-tuple-element.6, get-tuple-element.7, dynamic-update-slice.8)
}
"#;
        let x = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        // start=10 clamps to 3 (= 6 - 3): the window is x[3..6].
        let hi = Literal::from_data(crate::Data::I32(vec![10]), vec![]);
        let out = eval(text, &[&x, &hi]);
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![5.0, 6.0, 7.0]);
        assert_eq!(out[1].to_vec::<f32>().unwrap(), vec![-5.0, -6.0, -7.0]);
        assert_eq!(
            out[2].to_vec::<f32>().unwrap(),
            vec![1.0, 2.0, 3.0, -5.0, -6.0, -7.0]
        );
        // start=-2 clamps to 0.
        let lo = Literal::from_data(crate::Data::I32(vec![-2]), vec![]);
        let out = eval(text, &[&x, &lo]);
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![2.0, 3.0, 4.0]);
        assert_eq!(
            out[2].to_vec::<f32>().unwrap(),
            vec![-2.0, -3.0, -4.0, 4.0, 5.0, 6.0]
        );
        assert_tiers_bitwise(text, &[&x, &hi]);
    }
}
