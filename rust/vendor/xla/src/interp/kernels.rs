//! Typed execution kernels for the compiled register program.
//!
//! Every kernel operates on plain slices with all shape logic precomputed
//! by [`super::program`]: no `f64` boxing, no per-element coordinate
//! decoding, no allocation.  Elementwise f32 work runs through the fused
//! block loop ([`run_fused`]) over stack scratch registers; data movement
//! is a single gather pass over a compile-time index map.
//!
//! # The pinned lanes contract (dot + grouped reduce)
//!
//! Accumulating kernels use **8 lane accumulators with a pinned fold**,
//! and the order is part of the numeric contract:
//!
//! * per accumulated output element, 8 `f32` lanes start at `0.0`;
//! * contraction index `kk` contributes to lane `kk % 8`, ascending `kk`
//!   within each lane, as `lane += a * b` (mul then add, never FMA);
//! * all 8 lanes are always folded — zero lanes included — by the pinned
//!   pairwise tree [`hfold8`]:
//!   `((l0+l1) + (l2+l3)) + ((l4+l5) + (l6+l7))`;
//! * `dot` output is the fold; grouped-`reduce` output is `init + fold`.
//!
//! Every [`DotAlgo`] variant and both interpreter tiers implement this
//! one contract, so the cost model's plan selection and the
//! `DIVEBATCH_INTERP_TIER` switch change wall-clock only, never bits; the
//! Python mirror (python/mirror/interp.py) carries a single lanes
//! implementation that reproduces all of them.  Reduces whose index map
//! is not grouped-contiguous-Add keep the flat-ascending walk of the
//! tree-walk reference evaluator, bit for bit.  Change any order here and
//! the mirror + golden record must follow.

use super::cost::{DotAlgo, ReduceAlgo};
use super::fmath;
use super::program::{
    CmpDir, EwOp, FusedLoop, IntOp, Lane, PredOp, RegionFn, ScalarProgram, ScalarSrc,
};
use crate::InterpTier;

/// Block width of the fused elementwise loop: big enough to amortize the
/// per-op dispatch, small enough that the whole scratch file stays in L1.
pub(crate) const BLOCK: usize = 64;

/// Lane width of the SIMD tier (one AVX ymm register of f32s).
pub(crate) const LANES: usize = 8;

/// Register-block width (output columns) of the tiled dot variant.
pub(crate) const NR: usize = 4;

/// Column-tile width of the k-outer axpy dot variant (8 lane rows of TJ
/// f32s = 2 KiB of stack scratch).
pub(crate) const TJ: usize = 64;

/// The pinned pairwise horizontal fold of the 8 lane accumulators.
#[inline]
pub(crate) fn hfold8(l: [f32; LANES]) -> f32 {
    ((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7]))
}

#[inline]
fn ew1(op: EwOp, x: f32) -> f32 {
    match op {
        EwOp::Abs => x.abs(),
        EwOp::Neg => -x,
        EwOp::Exp => fmath::exp(x),
        EwOp::ExpM1 => fmath::exp_m1(x),
        EwOp::Log => fmath::ln(x),
        EwOp::Log1p => fmath::ln_1p(x),
        EwOp::Logistic => fmath::logistic(x),
        EwOp::Tanh => fmath::tanh(x),
        EwOp::Sqrt => fmath::sqrt(x),
        EwOp::Rsqrt => fmath::rsqrt(x),
        EwOp::Sign => {
            if x == 0.0 {
                0.0
            } else {
                x.signum()
            }
        }
        EwOp::Floor => x.floor(),
        EwOp::Ceil => x.ceil(),
        EwOp::Cos => fmath::cos(x),
        EwOp::Sin => fmath::sin(x),
        EwOp::Copy => x,
        _ => unreachable!("binary EwOp applied as unary"),
    }
}

#[inline]
fn ew2(op: EwOp, a: f32, b: f32) -> f32 {
    match op {
        EwOp::Add => a + b,
        EwOp::Sub => a - b,
        EwOp::Mul => a * b,
        EwOp::Div => a / b,
        EwOp::Max => a.max(b),
        EwOp::Min => a.min(b),
        EwOp::Pow => fmath::pow(a, b),
        EwOp::Rem => a % b,
        _ => unreachable!("unary EwOp applied as binary"),
    }
}

/// Run one fused f32 group: block-at-a-time over stack scratch registers,
/// each constituent op a monomorphized tight loop over the block.  The
/// SIMD tier runs arithmetic ops through explicit 8-wide inner loops
/// ([`binary_block_wide`]); elementwise math is order-free per element, so
/// both tiers produce identical bits.
pub(crate) fn run_fused(f: &FusedLoop, inputs: &[&[f32]], out: &mut [f32], tier: InterpTier) {
    debug_assert_eq!(inputs.len(), f.inputs.len());
    let mut regs = [[0f32; BLOCK]; super::program::MAX_FUSED_OPS];
    let last = f.ops.len() - 1;
    let wide = tier == InterpTier::Simd;
    let mut base = 0usize;
    while base < f.n {
        let len = BLOCK.min(f.n - base);
        for (ri, op) in f.ops.iter().enumerate() {
            // Split so the destination register can be written while the
            // earlier registers (all lower-indexed, by SSA order) are read.
            let (lo, hi) = regs.split_at_mut(ri);
            let dst = &mut hi[0][..len];
            match (op.a, op.b) {
                (a, None) => {
                    let av = lane(a, inputs, lo, base, len);
                    unary_block(op.op, av, dst);
                }
                (a, Some(b)) => {
                    let av = lane(a, inputs, lo, base, len);
                    let bv = lane(b, inputs, lo, base, len);
                    if wide {
                        binary_block_wide(op.op, av, bv, dst);
                    } else {
                        binary_block(op.op, av, bv, dst);
                    }
                }
            }
        }
        out[base..base + len].copy_from_slice(&regs[last][..len]);
        base += len;
    }
}

#[inline]
fn lane<'a>(
    l: Lane,
    inputs: &[&'a [f32]],
    regs: &'a [[f32; BLOCK]],
    base: usize,
    len: usize,
) -> &'a [f32] {
    match l {
        Lane::In(k) => &inputs[k as usize][base..base + len],
        Lane::Reg(r) => &regs[r as usize][..len],
    }
}

/// Monomorphized per-op unary loops (the match is hoisted out of the
/// element loop; each arm compiles to a straight-line vectorizable pass).
fn unary_block(op: EwOp, a: &[f32], dst: &mut [f32]) {
    macro_rules! lp {
        ($f:expr) => {
            for (d, &x) in dst.iter_mut().zip(a) {
                *d = $f(x);
            }
        };
    }
    match op {
        EwOp::Abs => lp!(f32::abs),
        EwOp::Neg => lp!(|x: f32| -x),
        EwOp::Exp => lp!(fmath::exp),
        EwOp::ExpM1 => lp!(fmath::exp_m1),
        EwOp::Log => lp!(fmath::ln),
        EwOp::Log1p => lp!(fmath::ln_1p),
        EwOp::Logistic => lp!(fmath::logistic),
        EwOp::Tanh => lp!(fmath::tanh),
        EwOp::Sqrt => lp!(fmath::sqrt),
        EwOp::Rsqrt => lp!(fmath::rsqrt),
        EwOp::Floor => lp!(f32::floor),
        EwOp::Ceil => lp!(f32::ceil),
        EwOp::Cos => lp!(fmath::cos),
        EwOp::Sin => lp!(fmath::sin),
        EwOp::Copy => dst.copy_from_slice(a),
        other => lp!(|x| ew1(other, x)),
    }
}

/// Monomorphized per-op binary loops (the scalar tier's form).
fn binary_block(op: EwOp, a: &[f32], b: &[f32], dst: &mut [f32]) {
    macro_rules! lp {
        ($f:expr) => {
            for ((d, &x), &y) in dst.iter_mut().zip(a).zip(b) {
                *d = $f(x, y);
            }
        };
    }
    match op {
        EwOp::Add => lp!(|x, y| x + y),
        EwOp::Sub => lp!(|x, y| x - y),
        EwOp::Mul => lp!(|x, y| x * y),
        EwOp::Div => lp!(|x, y| x / y),
        EwOp::Max => lp!(f32::max),
        EwOp::Min => lp!(f32::min),
        other => lp!(|x, y| ew2(other, x, y)),
    }
}

/// SIMD-tier binary loops: arithmetic ops run 8 lanes per iteration with a
/// scalar tail.  Per-element results are identical to [`binary_block`] —
/// the widening only removes loop-carried bookkeeping so the
/// autovectorizer can emit packed instructions.
fn binary_block_wide(op: EwOp, a: &[f32], b: &[f32], dst: &mut [f32]) {
    macro_rules! lp8 {
        ($f:expr) => {{
            let n = dst.len();
            let mut i = 0usize;
            while i + LANES <= n {
                let (aa, bb) = (&a[i..i + LANES], &b[i..i + LANES]);
                let d = &mut dst[i..i + LANES];
                for t in 0..LANES {
                    d[t] = $f(aa[t], bb[t]);
                }
                i += LANES;
            }
            while i < n {
                dst[i] = $f(a[i], b[i]);
                i += 1;
            }
        }};
    }
    match op {
        EwOp::Add => lp8!(|x: f32, y: f32| x + y),
        EwOp::Sub => lp8!(|x: f32, y: f32| x - y),
        EwOp::Mul => lp8!(|x: f32, y: f32| x * y),
        EwOp::Div => lp8!(|x: f32, y: f32| x / y),
        EwOp::Max => lp8!(f32::max),
        EwOp::Min => lp8!(f32::min),
        other => binary_block(other, a, b, dst),
    }
}

// -------------------------------------------------------- other dtypes

pub(crate) fn int_unary(op: IntOp, a: &[i32], dst: &mut [i32]) {
    let f: fn(i32) -> i32 = match op {
        IntOp::Abs => i32::wrapping_abs,
        IntOp::Neg => i32::wrapping_neg,
        IntOp::Sign => i32::signum,
        IntOp::Copy => |x| x,
        _ => unreachable!("binary IntOp applied as unary"),
    };
    for (d, &x) in dst.iter_mut().zip(a) {
        *d = f(x);
    }
}

pub(crate) fn int_binary(op: IntOp, a: &[i32], b: &[i32], dst: &mut [i32]) {
    let f: fn(i32, i32) -> i32 = match op {
        IntOp::Add => i32::wrapping_add,
        IntOp::Sub => i32::wrapping_sub,
        IntOp::Mul => i32::wrapping_mul,
        IntOp::Max => i32::max,
        IntOp::Min => i32::min,
        IntOp::And => |x, y| x & y,
        IntOp::Or => |x, y| x | y,
        IntOp::Xor => |x, y| x ^ y,
        _ => unreachable!("unary IntOp applied as binary"),
    };
    for ((d, &x), &y) in dst.iter_mut().zip(a).zip(b) {
        *d = f(x, y);
    }
}

pub(crate) fn pred_unary(op: PredOp, a: &[bool], dst: &mut [bool]) {
    match op {
        PredOp::Not => {
            for (d, &x) in dst.iter_mut().zip(a) {
                *d = !x;
            }
        }
        PredOp::Copy => dst.copy_from_slice(a),
        _ => unreachable!("binary PredOp applied as unary"),
    }
}

pub(crate) fn pred_binary(op: PredOp, a: &[bool], b: &[bool], dst: &mut [bool]) {
    let f: fn(bool, bool) -> bool = match op {
        PredOp::And => |x, y| x && y,
        PredOp::Or => |x, y| x || y,
        PredOp::Xor => |x, y| x ^ y,
        _ => unreachable!("unary PredOp applied as binary"),
    };
    for ((d, &x), &y) in dst.iter_mut().zip(a).zip(b) {
        *d = f(x, y);
    }
}

/// Compare loops.  `ord` is `None` only for NaN: all directions false
/// except NE (same semantics as the reference evaluator).
pub(crate) fn compare_f32(dir: CmpDir, a: &[f32], b: &[f32], dst: &mut [bool]) {
    macro_rules! lp {
        ($f:expr) => {
            for ((d, &x), &y) in dst.iter_mut().zip(a).zip(b) {
                *d = $f(x, y);
            }
        };
    }
    match dir {
        CmpDir::Eq => lp!(|x, y| x == y),
        CmpDir::Ne => lp!(|x, y| x != y),
        CmpDir::Lt => lp!(|x: f32, y: f32| x < y),
        CmpDir::Gt => lp!(|x: f32, y: f32| x > y),
        CmpDir::Le => lp!(|x: f32, y: f32| x <= y),
        CmpDir::Ge => lp!(|x: f32, y: f32| x >= y),
    }
}

pub(crate) fn compare_i32(dir: CmpDir, a: &[i32], b: &[i32], dst: &mut [bool]) {
    let f: fn(i32, i32) -> bool = match dir {
        CmpDir::Eq => |x, y| x == y,
        CmpDir::Ne => |x, y| x != y,
        CmpDir::Lt => |x, y| x < y,
        CmpDir::Gt => |x, y| x > y,
        CmpDir::Le => |x, y| x <= y,
        CmpDir::Ge => |x, y| x >= y,
    };
    for ((d, &x), &y) in dst.iter_mut().zip(a).zip(b) {
        *d = f(x, y);
    }
}

pub(crate) fn compare_pred(dir: CmpDir, a: &[bool], b: &[bool], dst: &mut [bool]) {
    let f: fn(bool, bool) -> bool = match dir {
        CmpDir::Eq => |x, y| x == y,
        CmpDir::Ne => |x, y| x != y,
        CmpDir::Lt => |x, y| !x & y,
        CmpDir::Gt => |x, y| x & !y,
        CmpDir::Le => |x, y| !x | y,
        CmpDir::Ge => |x, y| x | !y,
    };
    for ((d, &x), &y) in dst.iter_mut().zip(a).zip(b) {
        *d = f(x, y);
    }
}

/// `out[i] = if p { t } else { f }`, with an optional scalar predicate.
pub(crate) fn select<T: Copy>(
    p: &[bool],
    scalar_pred: bool,
    t: &[T],
    f: &[T],
    dst: &mut [T],
) {
    if scalar_pred {
        dst.copy_from_slice(if p[0] { t } else { f });
    } else {
        for (i, d) in dst.iter_mut().enumerate() {
            *d = if p[i] { t[i] } else { f[i] };
        }
    }
}

/// `out[i] = src[map[i]]` — broadcast/transpose/slice data movement.
pub(crate) fn gather<T: Copy>(src: &[T], map: &[u32], dst: &mut [T]) {
    for (d, &ix) in dst.iter_mut().zip(map) {
        *d = src[ix as usize];
    }
}

/// Pad: map entries of `u32::MAX` take the fill value.
pub(crate) fn pad<T: Copy>(src: &[T], fill: T, map: &[u32], dst: &mut [T]) {
    for (d, &ix) in dst.iter_mut().zip(map) {
        *d = if ix == u32::MAX { fill } else { src[ix as usize] };
    }
}

/// Concatenate one part into its precomputed output positions.
pub(crate) fn scatter_part<T: Copy>(src: &[T], place: &[u32], dst: &mut [T]) {
    for (&v, &ix) in src.iter().zip(place) {
        dst[ix as usize] = v;
    }
}

/// Dynamic-slice: copy the `sizes` window of `src` starting at the
/// (already clamped) per-dimension offsets `offs` into `dst`.  Start
/// indices are runtime values, so no precomputed map exists; the copy is
/// plain nested address arithmetic on both tiers.
pub(crate) fn dyn_slice<T: Copy>(
    src: &[T],
    src_dims: &[usize],
    offs: &[usize],
    sizes: &[usize],
    dst: &mut [T],
) {
    let src_st = super::parse::strides(src_dims);
    let out_st = super::parse::strides(sizes);
    for (flat, d) in dst.iter_mut().enumerate() {
        let c = super::parse::coords_of(flat, sizes, &out_st);
        let mut at = 0usize;
        for (dim, &ci) in c.iter().enumerate() {
            at += (offs[dim] + ci) * src_st[dim];
        }
        *d = src[at];
    }
}

/// Dynamic-update-slice: `dst` is `src` with the `upd_dims` window at
/// the (already clamped) offsets `offs` overwritten by `upd`.
pub(crate) fn dyn_update<T: Copy>(
    src: &[T],
    upd: &[T],
    src_dims: &[usize],
    offs: &[usize],
    upd_dims: &[usize],
    dst: &mut [T],
) {
    dst.copy_from_slice(src);
    let src_st = super::parse::strides(src_dims);
    let upd_st = super::parse::strides(upd_dims);
    for (flat, &v) in upd.iter().enumerate() {
        let c = super::parse::coords_of(flat, upd_dims, &upd_st);
        let mut at = 0usize;
        for (dim, &ci) in c.iter().enumerate() {
            at += (offs[dim] + ci) * src_st[dim];
        }
        dst[at] = v;
    }
}

// ------------------------------------------------------------------ dot

/// Single-contraction matmul over the collapsed (M, K) x (K, N) view.
///
/// The compile-time cost model picked `algo`; the scalar tier ignores it
/// and runs the generic gather form for every plan.  All paths follow the
/// pinned lanes contract (module docs), so every `(algo, tier)` pair
/// yields identical bits.
#[allow(clippy::too_many_arguments)]
pub(crate) fn dot(
    tier: InterpTier,
    algo: DotAlgo,
    l: &[f32],
    r: &[f32],
    l_base: &[u32],
    r_base: &[u32],
    l_kstride: usize,
    r_kstride: usize,
    k: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), l_base.len() * r_base.len());
    if tier == InterpTier::Scalar {
        return dot_lanes_gather(l, r, l_base, r_base, l_kstride, r_kstride, k, out);
    }
    match algo {
        DotAlgo::LanesContig => dot_lanes_contig(l, r, l_base, r_base, l_kstride, k, out),
        DotAlgo::LanesTiled => dot_lanes_tiled(l, r, l_base, r_base, k, out),
        DotAlgo::AxpyLanes => {
            dot_axpy_lanes(l, r, l_base, r_base.len(), l_kstride, r_kstride, k, out)
        }
        DotAlgo::LanesGather => {
            dot_lanes_gather(l, r, l_base, r_base, l_kstride, r_kstride, k, out)
        }
    }
}

/// Generic gather form: per output element, lane `kk % 8` accumulates the
/// strided product stream.  The scalar tier's only dot; the SIMD tier's
/// fallback for fully strided layouts.
#[allow(clippy::too_many_arguments)]
fn dot_lanes_gather(
    l: &[f32],
    r: &[f32],
    l_base: &[u32],
    r_base: &[u32],
    l_kstride: usize,
    r_kstride: usize,
    k: usize,
    out: &mut [f32],
) {
    let n = r_base.len();
    for (i, &lb) in l_base.iter().enumerate() {
        let lb = lb as usize;
        let row = &mut out[i * n..(i + 1) * n];
        for (o, &rb) in row.iter_mut().zip(r_base) {
            let rb = rb as usize;
            let mut lanes = [0f32; LANES];
            for kk in 0..k {
                lanes[kk % LANES] += l[lb + kk * l_kstride] * r[rb + kk * r_kstride];
            }
            *o = hfold8(lanes);
        }
    }
}

/// `r_kstride == 1`: per output element, 8-lane accumulation over
/// contiguous k-slices (contiguous lhs slice too when `l_kstride == 1`).
fn dot_lanes_contig(
    l: &[f32],
    r: &[f32],
    l_base: &[u32],
    r_base: &[u32],
    l_kstride: usize,
    k: usize,
    out: &mut [f32],
) {
    let n = r_base.len();
    for (i, &lb) in l_base.iter().enumerate() {
        let lb = lb as usize;
        let row = &mut out[i * n..(i + 1) * n];
        if l_kstride == 1 {
            let ls = &l[lb..lb + k];
            for (o, &rb) in row.iter_mut().zip(r_base) {
                let rb = rb as usize;
                *o = lanes_accum_contig(ls, &r[rb..rb + k], k);
            }
        } else {
            for (o, &rb) in row.iter_mut().zip(r_base) {
                let rb = rb as usize;
                let mut lanes = [0f32; LANES];
                for kk in 0..k {
                    lanes[kk % LANES] += l[lb + kk * l_kstride] * r[rb + kk];
                }
                *o = hfold8(lanes);
            }
        }
    }
}

/// Fully contiguous, `n >= NR`: register block of NR output columns
/// sharing each 8-wide lhs load, one lane file per column.
fn dot_lanes_tiled(
    l: &[f32],
    r: &[f32],
    l_base: &[u32],
    r_base: &[u32],
    k: usize,
    out: &mut [f32],
) {
    let n = r_base.len();
    let nc = k / LANES;
    for (i, &lb) in l_base.iter().enumerate() {
        let lb = lb as usize;
        let ls = &l[lb..lb + k];
        let row = &mut out[i * n..(i + 1) * n];
        let mut j = 0usize;
        while j + NR <= n {
            let mut acc = [[0f32; LANES]; NR];
            for c in 0..nc {
                let la = &ls[c * LANES..c * LANES + LANES];
                for (jj, accj) in acc.iter_mut().enumerate() {
                    let rb = r_base[j + jj] as usize;
                    let rs = &r[rb + c * LANES..rb + c * LANES + LANES];
                    for t in 0..LANES {
                        accj[t] += la[t] * rs[t];
                    }
                }
            }
            for t in 0..k - nc * LANES {
                let a = ls[nc * LANES + t];
                for (jj, accj) in acc.iter_mut().enumerate() {
                    accj[t] += a * r[r_base[j + jj] as usize + nc * LANES + t];
                }
            }
            for (jj, accj) in acc.iter().enumerate() {
                row[j + jj] = hfold8(*accj);
            }
            j += NR;
        }
        for jj in j..n {
            let rb = r_base[jj] as usize;
            row[jj] = lanes_accum_contig(ls, &r[rb..rb + k], k);
        }
    }
}

/// rhs free indices are exactly `0..n`: k-outer pass where each `kk`
/// broadcasts one lhs scalar against a unit-stride rhs row segment into
/// lane scratch row `kk % 8` — the inner loop is a pure axpy the
/// autovectorizer lowers to packed mul/add.  Columns are tiled by `TJ` so
/// the 8 x TJ scratch stays in L1.
#[allow(clippy::too_many_arguments)]
fn dot_axpy_lanes(
    l: &[f32],
    r: &[f32],
    l_base: &[u32],
    n: usize,
    l_kstride: usize,
    r_kstride: usize,
    k: usize,
    out: &mut [f32],
) {
    for (i, &lb) in l_base.iter().enumerate() {
        let lb = lb as usize;
        let row = &mut out[i * n..(i + 1) * n];
        let mut j0 = 0usize;
        while j0 < n {
            let tj = TJ.min(n - j0);
            let mut lanes = [[0f32; TJ]; LANES];
            for kk in 0..k {
                let a = l[lb + kk * l_kstride];
                let rrow = &r[kk * r_kstride + j0..kk * r_kstride + j0 + tj];
                let lt = &mut lanes[kk % LANES][..tj];
                for (o, &b) in lt.iter_mut().zip(rrow) {
                    *o += a * b;
                }
            }
            for (jj, o) in row[j0..j0 + tj].iter_mut().enumerate() {
                let mut v = [0f32; LANES];
                for t in 0..LANES {
                    v[t] = lanes[t][jj];
                }
                *o = hfold8(v);
            }
            j0 += tj;
        }
    }
}

/// 8-lane accumulation over two contiguous k-slices (the lanes contract
/// on unit strides).  Dispatches to the AVX form when the CPU has it —
/// `_mm256_mul_ps`/`_mm256_add_ps` are per-lane IEEE-exact, so the bits
/// are identical to the portable loop.
#[inline]
fn lanes_accum_contig(ls: &[f32], rs: &[f32], k: usize) -> f32 {
    #[cfg(target_arch = "x86_64")]
    {
        if k >= 2 * LANES && std::arch::is_x86_feature_detected!("avx") {
            // SAFETY: guarded by the runtime AVX check above.
            return unsafe { lanes_accum_contig_avx(ls, rs, k) };
        }
    }
    lanes_accum_contig_portable(ls, rs, k)
}

fn lanes_accum_contig_portable(ls: &[f32], rs: &[f32], k: usize) -> f32 {
    let mut lanes = [0f32; LANES];
    let mut ch_l = ls[..k].chunks_exact(LANES);
    let mut ch_r = rs[..k].chunks_exact(LANES);
    for (cl, cr) in (&mut ch_l).zip(&mut ch_r) {
        for t in 0..LANES {
            lanes[t] += cl[t] * cr[t];
        }
    }
    for (t, (&a, &b)) in ch_l.remainder().iter().zip(ch_r.remainder()).enumerate() {
        lanes[t] += a * b;
    }
    hfold8(lanes)
}

/// AVX twin of [`lanes_accum_contig_portable`]: one ymm register is
/// exactly the 8-lane accumulator file, updated in the same ascending
/// chunk order with separate mul and add (no FMA), then stored and folded
/// by the same pinned tree.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn lanes_accum_contig_avx(ls: &[f32], rs: &[f32], k: usize) -> f32 {
    use std::arch::x86_64::{
        _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_setzero_ps, _mm256_storeu_ps,
    };
    let nc = k / LANES;
    let mut acc = _mm256_setzero_ps();
    for c in 0..nc {
        let a = _mm256_loadu_ps(ls.as_ptr().add(c * LANES));
        let b = _mm256_loadu_ps(rs.as_ptr().add(c * LANES));
        acc = _mm256_add_ps(acc, _mm256_mul_ps(a, b));
    }
    let mut lanes = [0f32; LANES];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    for t in 0..k - nc * LANES {
        lanes[t] += ls[nc * LANES + t] * rs[nc * LANES + t];
    }
    hfold8(lanes)
}

// ----------------------------------------------------------------- conv

/// k-extent of the stack weight tile of the blocked conv kernel
/// (`NR * CONV_KC` f32s = 32 KiB).  Convolutions with `k` beyond it run
/// the generic gather loop instead — same bits, no tile.
pub(crate) const CONV_KC: usize = 2048;

/// Fused blocked-direct convolution, one feature group per call.
///
/// Computes exactly what the im2col path computes — for output element
/// `(i, j)`: `lanes[kk % 8] += patch(i, kk) * w(kk, j)` ascending `kk`,
/// then [`hfold8`] — but gathers `patch(i, kk) = lhs[patch_map[i*k+kk]]`
/// (0.0 where the map says halo) straight into registers instead of
/// materializing the `[m, k]` patch matrix, and pre-gathers the `[k, w]`
/// weight tile of each [`NR`]-wide output-channel block once into stack
/// scratch.  Halo entries still contribute `0.0 * w` products (never
/// skipped): `0.0 * w` can be `-0.0`, and the contract is mul-then-add.
///
/// Results are written through `place` directly — no dot-accumulator
/// scratch either.  Bit-identical to `pad` + `gather` + [`dot`] +
/// [`scatter_part`] on both tiers by the pinned lanes contract.
#[allow(clippy::too_many_arguments)]
pub(crate) fn conv_blocked(
    tier: InterpTier,
    l: &[f32],
    r: &[f32],
    patch_map: &[u32],
    w_map: &[u32],
    place: &[u32],
    m: usize,
    k: usize,
    ng: usize,
    out: &mut [f32],
) {
    if tier == InterpTier::Scalar {
        conv_blocked_scalar(l, r, patch_map, w_map, place, m, k, ng, out);
    } else {
        conv_blocked_simd(l, r, patch_map, w_map, place, m, k, ng, out);
    }
}

#[inline]
fn lhs_at(l: &[f32], ix: u32) -> f32 {
    if ix == u32::MAX {
        0.0
    } else {
        l[ix as usize]
    }
}

/// SIMD-tier blocked conv: the loop nest of [`dot_lanes_tiled`] with the
/// operand loads replaced by map gathers.  Column blocks outer (each
/// pre-gathers its `[k, w]` weight tile into stack scratch once), rows
/// inner (each 8-lane patch chunk is gathered once and shared by all
/// [`NR`] columns of the block).  `k` beyond [`CONV_KC`] (no realistic
/// conv) falls back to the generic loop — identical bits either way.
#[allow(clippy::too_many_arguments)]
fn conv_blocked_simd(
    l: &[f32],
    r: &[f32],
    patch_map: &[u32],
    w_map: &[u32],
    place: &[u32],
    m: usize,
    k: usize,
    ng: usize,
    out: &mut [f32],
) {
    if k > CONV_KC {
        return conv_blocked_scalar(l, r, patch_map, w_map, place, m, k, ng, out);
    }
    let mut wt = [[0f32; CONV_KC]; NR];
    let nc = k / LANES;
    let mut j0 = 0usize;
    while j0 < ng {
        let w = NR.min(ng - j0);
        for (jj, wtj) in wt.iter_mut().enumerate().take(w) {
            for (c, o) in wtj.iter_mut().enumerate().take(k) {
                *o = r[w_map[c * ng + j0 + jj] as usize];
            }
        }
        for i in 0..m {
            let pm = &patch_map[i * k..(i + 1) * k];
            let mut acc = [[0f32; LANES]; NR];
            for c in 0..nc {
                let mut la = [0f32; LANES];
                for (t, o) in la.iter_mut().enumerate() {
                    *o = lhs_at(l, pm[c * LANES + t]);
                }
                for (jj, accj) in acc.iter_mut().enumerate().take(w) {
                    let ws = &wt[jj][c * LANES..c * LANES + LANES];
                    for t in 0..LANES {
                        accj[t] += la[t] * ws[t];
                    }
                }
            }
            for t in 0..k - nc * LANES {
                let a = lhs_at(l, pm[nc * LANES + t]);
                for (jj, accj) in acc.iter_mut().enumerate().take(w) {
                    accj[t] += a * wt[jj][nc * LANES + t];
                }
            }
            for (jj, accj) in acc.iter().enumerate().take(w) {
                out[place[i * ng + j0 + jj] as usize] = hfold8(*accj);
            }
        }
        j0 += w;
    }
}

/// Scalar-tier twin of [`conv_blocked_simd`]: the contract written as the
/// plain per-output-element loop (exactly [`dot_lanes_gather`] with map
/// gathers) — identical bits by construction.
#[allow(clippy::too_many_arguments)]
fn conv_blocked_scalar(
    l: &[f32],
    r: &[f32],
    patch_map: &[u32],
    w_map: &[u32],
    place: &[u32],
    m: usize,
    k: usize,
    ng: usize,
    out: &mut [f32],
) {
    for i in 0..m {
        let pm = &patch_map[i * k..(i + 1) * k];
        for j in 0..ng {
            let mut lanes = [0f32; LANES];
            for (kk, &ix) in pm.iter().enumerate() {
                lanes[kk % LANES] += lhs_at(l, ix) * r[w_map[kk * ng + j] as usize];
            }
            out[place[i * ng + j] as usize] = hfold8(lanes);
        }
    }
}

// --------------------------------------------------------------- reduce

/// Apply a compiled scalar region program to `(acc, x)`.  The register
/// file is a small stack array (the lowering caps regions at
/// [`super::program::MAX_REGION_OPS`] ops).
#[inline]
pub(crate) fn region_apply(p: &ScalarProgram, acc: f32, x: f32) -> f32 {
    let mut regs = [0f32; super::program::MAX_REGION_OPS];
    let read = |s: ScalarSrc, regs: &[f32]| -> f32 {
        match s {
            ScalarSrc::Acc => acc,
            ScalarSrc::X => x,
            ScalarSrc::Const(c) => p.consts[c as usize],
            ScalarSrc::Reg(r) => regs[r as usize],
        }
    };
    for (ri, op) in p.ops.iter().enumerate() {
        let v = match op.b {
            None => ew1(op.op, read(op.a, &regs)),
            Some(b) => ew2(op.op, read(op.a, &regs), read(b, &regs)),
        };
        regs[ri] = v;
    }
    read(p.result, &regs)
}

/// Reduce through the region kernel.  Grouped-contiguous Add plans (the
/// cost model detected `map[i] == i / group`) run the pinned lanes
/// contract per output element; everything else keeps the flat-ascending
/// walk, bit-identical to the reference evaluator.
pub(crate) fn reduce(
    tier: InterpTier,
    algo: ReduceAlgo,
    data: &[f32],
    init: f32,
    map: &[u32],
    region: &RegionFn,
    out: &mut [f32],
) {
    if let ReduceAlgo::GroupedLanes { group } = algo {
        debug_assert!(matches!(region, RegionFn::Add));
        if tier == InterpTier::Simd {
            reduce_grouped_lanes(data, init, group, out);
        } else {
            reduce_grouped_lanes_scalar(data, init, group, out);
        }
        return;
    }
    out.fill(init);
    match region {
        RegionFn::Add => {
            for (&x, &of) in data.iter().zip(map) {
                out[of as usize] += x;
            }
        }
        RegionFn::Mul => {
            for (&x, &of) in data.iter().zip(map) {
                out[of as usize] *= x;
            }
        }
        RegionFn::Max => {
            for (&x, &of) in data.iter().zip(map) {
                let o = &mut out[of as usize];
                *o = o.max(x);
            }
        }
        RegionFn::Min => {
            for (&x, &of) in data.iter().zip(map) {
                let o = &mut out[of as usize];
                *o = o.min(x);
            }
        }
        RegionFn::Program(p) => {
            for (&x, &of) in data.iter().zip(map) {
                let o = &mut out[of as usize];
                *o = region_apply(p, *o, x);
            }
        }
    }
}

/// SIMD-tier grouped-Add: per output element, 8-wide chunked lane
/// accumulation over its `group` consecutive inputs, scalar tail, pinned
/// fold, `init` added once after the fold.
fn reduce_grouped_lanes(data: &[f32], init: f32, group: usize, out: &mut [f32]) {
    for (o, grp) in out.iter_mut().zip(data.chunks_exact(group)) {
        let mut lanes = [0f32; LANES];
        let mut ch = grp.chunks_exact(LANES);
        for c in &mut ch {
            for t in 0..LANES {
                lanes[t] += c[t];
            }
        }
        for (t, &x) in ch.remainder().iter().enumerate() {
            lanes[t] += x;
        }
        *o = init + hfold8(lanes);
    }
}

/// Scalar-tier twin of [`reduce_grouped_lanes`]: same lane indexing
/// (`kk % 8`, ascending), same fold, written as a plain scalar loop —
/// identical bits by construction.
fn reduce_grouped_lanes_scalar(data: &[f32], init: f32, group: usize, out: &mut [f32]) {
    for (o, grp) in out.iter_mut().zip(data.chunks_exact(group)) {
        let mut lanes = [0f32; LANES];
        for (kk, &x) in grp.iter().enumerate() {
            lanes[kk % LANES] += x;
        }
        *o = init + hfold8(lanes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lanes_ref(vals: &[(f32, f32)]) -> f32 {
        // The contract, written the slow obvious way.
        let mut lanes = [0f32; LANES];
        for (kk, &(a, b)) in vals.iter().enumerate() {
            lanes[kk % LANES] += a * b;
        }
        hfold8(lanes)
    }

    #[test]
    fn all_dot_variants_agree_bitwise() {
        // m=3, n=5, k=11 (odd k exercises the tail), fully contiguous
        // lhs [3,11] / rhs [11,5] with iota-style base tables so every
        // variant's precondition holds and all can be compared.
        let (m, n, k) = (3usize, 5usize, 11usize);
        let l: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.37).sin() + 0.01).collect();
        let r: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.21).cos() - 0.02).collect();
        // lhs [m,k] strides: row base i*k, kstride 1.
        let l_base: Vec<u32> = (0..m).map(|i| (i * k) as u32).collect();
        // rhs [k,n] strides: col base j, kstride n.
        let r_base_strided: Vec<u32> = (0..n as u32).collect();
        let mut want = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let vals: Vec<(f32, f32)> =
                    (0..k).map(|kk| (l[i * k + kk], r[kk * n + j])).collect();
                want[i * n + j] = lanes_ref(&vals);
            }
        }
        // AxpyLanes + LanesGather on the strided rhs layout, both tiers.
        for algo in [DotAlgo::AxpyLanes, DotAlgo::LanesGather] {
            for tier in [InterpTier::Simd, InterpTier::Scalar] {
                let mut got = vec![0f32; m * n];
                dot(tier, algo, &l, &r, &l_base, &r_base_strided, 1, n, k, &mut got);
                assert_eq!(
                    got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{algo:?} {tier:?}"
                );
            }
        }
        // Contig variants on the transposed rhs layout [n,k] (r_kstride=1).
        let rt: Vec<f32> = {
            let mut v = vec![0f32; n * k];
            for j in 0..n {
                for kk in 0..k {
                    v[j * k + kk] = r[kk * n + j];
                }
            }
            v
        };
        let r_base_contig: Vec<u32> = (0..n).map(|j| (j * k) as u32).collect();
        for algo in [DotAlgo::LanesContig, DotAlgo::LanesTiled, DotAlgo::LanesGather] {
            for tier in [InterpTier::Simd, InterpTier::Scalar] {
                let mut got = vec![0f32; m * n];
                dot(tier, algo, &l, &rt, &l_base, &r_base_contig, 1, 1, k, &mut got);
                assert_eq!(
                    got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{algo:?} {tier:?}"
                );
            }
        }
    }

    #[test]
    fn conv_blocked_matches_im2col_composition_bitwise() {
        use super::super::cost::select_dot_algo;
        // Pseudo-random but deterministic maps: halo entries (u32::MAX)
        // sprinkled in, scattered weight/output placement, odd k and every
        // ng shape the register block can see (< NR, == NR, % NR != 0,
        // multiple blocks).
        for (m, k, ng) in [
            (1usize, 1usize, 1usize),
            (7, 11, 1),
            (5, 8, 3),
            (9, 27, 4),
            (6, 13, 5),
            (17, 72, 16),
            (3, 9, 21),
        ] {
            let ll = 2 * m * k + 3;
            let rl = 2 * k * ng + 5;
            let l: Vec<f32> = (0..ll).map(|i| (i as f32 * 0.37).sin() + 0.01).collect();
            let r: Vec<f32> = (0..rl).map(|i| (i as f32 * 0.21).cos() - 0.02).collect();
            let patch_map: Vec<u32> = (0..m * k)
                .map(|i| {
                    if i % 7 == 3 {
                        u32::MAX // halo: must still contribute 0.0 * w
                    } else {
                        ((i * 131) % ll) as u32
                    }
                })
                .collect();
            let w_map: Vec<u32> = (0..k * ng).map(|i| ((i * 37) % rl) as u32).collect();
            // An arbitrary permutation of the output positions.
            let mut place: Vec<u32> = (0..(m * ng) as u32).collect();
            place.reverse();
            place.rotate_left((m * ng) / 3);

            // The im2col composition exactly as exec.rs runs it.
            let mut patch = vec![0f32; m * k];
            let mut w = vec![0f32; k * ng];
            let mut acc = vec![0f32; m * ng];
            let mut want = vec![0f32; m * ng];
            pad(&l, 0.0, &patch_map, &mut patch);
            gather(&r, &w_map, &mut w);
            let l_base: Vec<u32> = (0..m).map(|i| (i * k) as u32).collect();
            let r_base: Vec<u32> = (0..ng as u32).collect();
            let algo = select_dot_algo(m, ng, k, 1, ng, true);
            dot(
                InterpTier::Simd,
                algo,
                &patch,
                &w,
                &l_base,
                &r_base,
                1,
                ng,
                k,
                &mut acc,
            );
            scatter_part(&acc, &place, &mut want);

            for tier in [InterpTier::Simd, InterpTier::Scalar] {
                let mut got = vec![0f32; m * ng];
                conv_blocked(tier, &l, &r, &patch_map, &w_map, &place, m, k, ng, &mut got);
                assert_eq!(
                    got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "m={m} k={k} ng={ng} {tier:?}"
                );
            }
        }
    }

    #[test]
    fn grouped_reduce_tiers_agree_bitwise() {
        for group in [1usize, 3, 8, 13, 64] {
            let out_elems = 7usize;
            let data: Vec<f32> = (0..group * out_elems)
                .map(|i| (i as f32 * 0.13).sin() * 3.0)
                .collect();
            let mut a = vec![0f32; out_elems];
            let mut b = vec![0f32; out_elems];
            reduce_grouped_lanes(&data, 0.5, group, &mut a);
            reduce_grouped_lanes_scalar(&data, 0.5, group, &mut b);
            assert_eq!(
                a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "group {group}"
            );
        }
    }

    #[test]
    fn contig_accum_avx_matches_portable() {
        // Exercises the AVX dispatch when the host has it; on other hosts
        // this still pins the portable path against the contract.
        for k in [1usize, 7, 8, 9, 16, 31, 64, 129] {
            let a: Vec<f32> = (0..k).map(|i| (i as f32 * 0.7).sin()).collect();
            let b: Vec<f32> = (0..k).map(|i| (i as f32 * 0.3).cos()).collect();
            let got = lanes_accum_contig(&a, &b, k);
            let want = lanes_ref(&a.iter().copied().zip(b.iter().copied()).collect::<Vec<_>>());
            assert_eq!(got.to_bits(), want.to_bits(), "k={k}");
        }
    }

    #[test]
    fn wide_binary_block_matches_scalar() {
        for n in [1usize, 7, 8, 9, 63, 64] {
            let a: Vec<f32> = (0..n).map(|i| i as f32 * 0.5 - 3.0).collect();
            let b: Vec<f32> = (0..n).map(|i| 2.0 - i as f32 * 0.25).collect();
            for op in [EwOp::Add, EwOp::Sub, EwOp::Mul, EwOp::Div, EwOp::Max, EwOp::Min] {
                let mut x = vec![0f32; n];
                let mut y = vec![0f32; n];
                binary_block(op, &a, &b, &mut x);
                binary_block_wide(op, &a, &b, &mut y);
                assert_eq!(
                    x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    y.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{op:?} n={n}"
                );
            }
        }
    }
}
