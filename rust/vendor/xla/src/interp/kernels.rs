//! Typed execution kernels for the compiled register program.
//!
//! Every kernel operates on plain slices with all shape logic precomputed
//! by [`super::program`]: no `f64` boxing, no per-element coordinate
//! decoding, no allocation.  Elementwise f32 work runs through the fused
//! block loop ([`run_fused`]) over stack scratch registers; data movement
//! is a single gather pass over a compile-time index map; `dot` walks
//! contiguous slices (k-inner when the rhs contraction stride is 1,
//! k-outer axpy otherwise — both accumulate each output element in
//! ascending-k order, so the two loop shapes are bit-identical); `reduce`
//! folds flat-ascending through a compiled region kernel.
//!
//! Numeric order is part of the contract: the Python mirror
//! (python/mirror/interp.py) reproduces these loops bit for bit to
//! generate the committed golden run record.  Change an iteration order
//! here and the mirror + golden must follow.

use super::fmath;
use super::program::{
    CmpDir, EwOp, FusedLoop, IntOp, Lane, PredOp, RegionFn, ScalarProgram, ScalarSrc,
};

/// Block width of the fused elementwise loop: big enough to amortize the
/// per-op dispatch, small enough that the whole scratch file stays in L1.
pub(crate) const BLOCK: usize = 64;

#[inline]
fn ew1(op: EwOp, x: f32) -> f32 {
    match op {
        EwOp::Abs => x.abs(),
        EwOp::Neg => -x,
        EwOp::Exp => fmath::exp(x),
        EwOp::ExpM1 => fmath::exp_m1(x),
        EwOp::Log => fmath::ln(x),
        EwOp::Log1p => fmath::ln_1p(x),
        EwOp::Logistic => fmath::logistic(x),
        EwOp::Tanh => fmath::tanh(x),
        EwOp::Sqrt => fmath::sqrt(x),
        EwOp::Rsqrt => fmath::rsqrt(x),
        EwOp::Sign => {
            if x == 0.0 {
                0.0
            } else {
                x.signum()
            }
        }
        EwOp::Floor => x.floor(),
        EwOp::Ceil => x.ceil(),
        EwOp::Cos => fmath::cos(x),
        EwOp::Sin => fmath::sin(x),
        EwOp::Copy => x,
        _ => unreachable!("binary EwOp applied as unary"),
    }
}

#[inline]
fn ew2(op: EwOp, a: f32, b: f32) -> f32 {
    match op {
        EwOp::Add => a + b,
        EwOp::Sub => a - b,
        EwOp::Mul => a * b,
        EwOp::Div => a / b,
        EwOp::Max => a.max(b),
        EwOp::Min => a.min(b),
        EwOp::Pow => fmath::pow(a, b),
        EwOp::Rem => a % b,
        _ => unreachable!("unary EwOp applied as binary"),
    }
}

/// Run one fused f32 group: block-at-a-time over stack scratch registers,
/// each constituent op a monomorphized tight loop over the block.
pub(crate) fn run_fused(f: &FusedLoop, inputs: &[&[f32]], out: &mut [f32]) {
    debug_assert_eq!(inputs.len(), f.inputs.len());
    let mut regs = [[0f32; BLOCK]; super::program::MAX_FUSED_OPS];
    let last = f.ops.len() - 1;
    let mut base = 0usize;
    while base < f.n {
        let len = BLOCK.min(f.n - base);
        for (ri, op) in f.ops.iter().enumerate() {
            // Split so the destination register can be written while the
            // earlier registers (all lower-indexed, by SSA order) are read.
            let (lo, hi) = regs.split_at_mut(ri);
            let dst = &mut hi[0][..len];
            match (op.a, op.b) {
                (a, None) => {
                    let av = lane(a, inputs, lo, base, len);
                    unary_block(op.op, av, dst);
                }
                (a, Some(b)) => {
                    let av = lane(a, inputs, lo, base, len);
                    let bv = lane(b, inputs, lo, base, len);
                    binary_block(op.op, av, bv, dst);
                }
            }
        }
        out[base..base + len].copy_from_slice(&regs[last][..len]);
        base += len;
    }
}

#[inline]
fn lane<'a>(
    l: Lane,
    inputs: &[&'a [f32]],
    regs: &'a [[f32; BLOCK]],
    base: usize,
    len: usize,
) -> &'a [f32] {
    match l {
        Lane::In(k) => &inputs[k as usize][base..base + len],
        Lane::Reg(r) => &regs[r as usize][..len],
    }
}

/// Monomorphized per-op unary loops (the match is hoisted out of the
/// element loop; each arm compiles to a straight-line vectorizable pass).
fn unary_block(op: EwOp, a: &[f32], dst: &mut [f32]) {
    macro_rules! lp {
        ($f:expr) => {
            for (d, &x) in dst.iter_mut().zip(a) {
                *d = $f(x);
            }
        };
    }
    match op {
        EwOp::Abs => lp!(f32::abs),
        EwOp::Neg => lp!(|x: f32| -x),
        EwOp::Exp => lp!(fmath::exp),
        EwOp::ExpM1 => lp!(fmath::exp_m1),
        EwOp::Log => lp!(fmath::ln),
        EwOp::Log1p => lp!(fmath::ln_1p),
        EwOp::Logistic => lp!(fmath::logistic),
        EwOp::Tanh => lp!(fmath::tanh),
        EwOp::Sqrt => lp!(fmath::sqrt),
        EwOp::Rsqrt => lp!(fmath::rsqrt),
        EwOp::Floor => lp!(f32::floor),
        EwOp::Ceil => lp!(f32::ceil),
        EwOp::Cos => lp!(fmath::cos),
        EwOp::Sin => lp!(fmath::sin),
        EwOp::Copy => dst.copy_from_slice(a),
        other => lp!(|x| ew1(other, x)),
    }
}

/// Monomorphized per-op binary loops.
fn binary_block(op: EwOp, a: &[f32], b: &[f32], dst: &mut [f32]) {
    macro_rules! lp {
        ($f:expr) => {
            for ((d, &x), &y) in dst.iter_mut().zip(a).zip(b) {
                *d = $f(x, y);
            }
        };
    }
    match op {
        EwOp::Add => lp!(|x, y| x + y),
        EwOp::Sub => lp!(|x, y| x - y),
        EwOp::Mul => lp!(|x, y| x * y),
        EwOp::Div => lp!(|x, y| x / y),
        EwOp::Max => lp!(f32::max),
        EwOp::Min => lp!(f32::min),
        other => lp!(|x, y| ew2(other, x, y)),
    }
}

// -------------------------------------------------------- other dtypes

pub(crate) fn int_unary(op: IntOp, a: &[i32], dst: &mut [i32]) {
    let f: fn(i32) -> i32 = match op {
        IntOp::Abs => i32::wrapping_abs,
        IntOp::Neg => i32::wrapping_neg,
        IntOp::Sign => i32::signum,
        IntOp::Copy => |x| x,
        _ => unreachable!("binary IntOp applied as unary"),
    };
    for (d, &x) in dst.iter_mut().zip(a) {
        *d = f(x);
    }
}

pub(crate) fn int_binary(op: IntOp, a: &[i32], b: &[i32], dst: &mut [i32]) {
    let f: fn(i32, i32) -> i32 = match op {
        IntOp::Add => i32::wrapping_add,
        IntOp::Sub => i32::wrapping_sub,
        IntOp::Mul => i32::wrapping_mul,
        IntOp::Max => i32::max,
        IntOp::Min => i32::min,
        IntOp::And => |x, y| x & y,
        IntOp::Or => |x, y| x | y,
        IntOp::Xor => |x, y| x ^ y,
        _ => unreachable!("unary IntOp applied as binary"),
    };
    for ((d, &x), &y) in dst.iter_mut().zip(a).zip(b) {
        *d = f(x, y);
    }
}

pub(crate) fn pred_unary(op: PredOp, a: &[bool], dst: &mut [bool]) {
    match op {
        PredOp::Not => {
            for (d, &x) in dst.iter_mut().zip(a) {
                *d = !x;
            }
        }
        PredOp::Copy => dst.copy_from_slice(a),
        _ => unreachable!("binary PredOp applied as unary"),
    }
}

pub(crate) fn pred_binary(op: PredOp, a: &[bool], b: &[bool], dst: &mut [bool]) {
    let f: fn(bool, bool) -> bool = match op {
        PredOp::And => |x, y| x && y,
        PredOp::Or => |x, y| x || y,
        PredOp::Xor => |x, y| x ^ y,
        _ => unreachable!("unary PredOp applied as binary"),
    };
    for ((d, &x), &y) in dst.iter_mut().zip(a).zip(b) {
        *d = f(x, y);
    }
}

/// Compare loops.  `ord` is `None` only for NaN: all directions false
/// except NE (same semantics as the reference evaluator).
pub(crate) fn compare_f32(dir: CmpDir, a: &[f32], b: &[f32], dst: &mut [bool]) {
    macro_rules! lp {
        ($f:expr) => {
            for ((d, &x), &y) in dst.iter_mut().zip(a).zip(b) {
                *d = $f(x, y);
            }
        };
    }
    match dir {
        CmpDir::Eq => lp!(|x, y| x == y),
        CmpDir::Ne => lp!(|x, y| x != y),
        CmpDir::Lt => lp!(|x: f32, y: f32| x < y),
        CmpDir::Gt => lp!(|x: f32, y: f32| x > y),
        CmpDir::Le => lp!(|x: f32, y: f32| x <= y),
        CmpDir::Ge => lp!(|x: f32, y: f32| x >= y),
    }
}

pub(crate) fn compare_i32(dir: CmpDir, a: &[i32], b: &[i32], dst: &mut [bool]) {
    let f: fn(i32, i32) -> bool = match dir {
        CmpDir::Eq => |x, y| x == y,
        CmpDir::Ne => |x, y| x != y,
        CmpDir::Lt => |x, y| x < y,
        CmpDir::Gt => |x, y| x > y,
        CmpDir::Le => |x, y| x <= y,
        CmpDir::Ge => |x, y| x >= y,
    };
    for ((d, &x), &y) in dst.iter_mut().zip(a).zip(b) {
        *d = f(x, y);
    }
}

pub(crate) fn compare_pred(dir: CmpDir, a: &[bool], b: &[bool], dst: &mut [bool]) {
    let f: fn(bool, bool) -> bool = match dir {
        CmpDir::Eq => |x, y| x == y,
        CmpDir::Ne => |x, y| x != y,
        CmpDir::Lt => |x, y| !x & y,
        CmpDir::Gt => |x, y| x & !y,
        CmpDir::Le => |x, y| !x | y,
        CmpDir::Ge => |x, y| x | !y,
    };
    for ((d, &x), &y) in dst.iter_mut().zip(a).zip(b) {
        *d = f(x, y);
    }
}

/// `out[i] = if p { t } else { f }`, with an optional scalar predicate.
pub(crate) fn select<T: Copy>(
    p: &[bool],
    scalar_pred: bool,
    t: &[T],
    f: &[T],
    dst: &mut [T],
) {
    if scalar_pred {
        dst.copy_from_slice(if p[0] { t } else { f });
    } else {
        for (i, d) in dst.iter_mut().enumerate() {
            *d = if p[i] { t[i] } else { f[i] };
        }
    }
}

/// `out[i] = src[map[i]]` — broadcast/transpose/slice data movement.
pub(crate) fn gather<T: Copy>(src: &[T], map: &[u32], dst: &mut [T]) {
    for (d, &ix) in dst.iter_mut().zip(map) {
        *d = src[ix as usize];
    }
}

/// Pad: map entries of `u32::MAX` take the fill value.
pub(crate) fn pad<T: Copy>(src: &[T], fill: T, map: &[u32], dst: &mut [T]) {
    for (d, &ix) in dst.iter_mut().zip(map) {
        *d = if ix == u32::MAX { fill } else { src[ix as usize] };
    }
}

/// Concatenate one part into its precomputed output positions.
pub(crate) fn scatter_part<T: Copy>(src: &[T], place: &[u32], dst: &mut [T]) {
    for (&v, &ix) in src.iter().zip(place) {
        dst[ix as usize] = v;
    }
}

/// Single-contraction matmul over the collapsed (M, K) x (K, N) view.
///
/// Both loop shapes accumulate each output element in ascending-k order
/// (mul-then-add, no FMA contraction), so they are bit-identical to each
/// other and to the reference evaluator's per-element loop.
#[allow(clippy::too_many_arguments)]
pub(crate) fn dot(
    l: &[f32],
    r: &[f32],
    l_base: &[u32],
    r_base: &[u32],
    l_kstride: usize,
    r_kstride: usize,
    k: usize,
    out: &mut [f32],
) {
    let m = l_base.len();
    let n = r_base.len();
    debug_assert_eq!(out.len(), m * n);
    if r_kstride == 1 {
        // rhs contraction is contiguous: k-inner dot over slices.
        for (i, &lb) in l_base.iter().enumerate() {
            let lb = lb as usize;
            let row = &mut out[i * n..(i + 1) * n];
            if l_kstride == 1 {
                let ls = &l[lb..lb + k];
                for (o, &rb) in row.iter_mut().zip(r_base) {
                    let rs = &r[rb as usize..rb as usize + k];
                    let mut acc = 0.0f32;
                    for (&a, &b) in ls.iter().zip(rs) {
                        acc += a * b;
                    }
                    *o = acc;
                }
            } else {
                for (o, &rb) in row.iter_mut().zip(r_base) {
                    let rb = rb as usize;
                    let mut acc = 0.0f32;
                    for kk in 0..k {
                        acc += l[lb + kk * l_kstride] * r[rb + kk];
                    }
                    *o = acc;
                }
            }
        }
    } else {
        // rhs contraction is strided: k-outer axpy keeps the inner loop
        // over the output row (ascending-k per element, same bits).
        for (i, &lb) in l_base.iter().enumerate() {
            let lb = lb as usize;
            let row = &mut out[i * n..(i + 1) * n];
            row.fill(0.0);
            for kk in 0..k {
                let a = l[lb + kk * l_kstride];
                let roff = kk * r_kstride;
                for (o, &rb) in row.iter_mut().zip(r_base) {
                    *o += a * r[rb as usize + roff];
                }
            }
        }
    }
}

/// Apply a compiled scalar region program to `(acc, x)`.  The register
/// file is a small stack array (the lowering caps regions at
/// [`super::program::MAX_REGION_OPS`] ops).
#[inline]
pub(crate) fn region_apply(p: &ScalarProgram, acc: f32, x: f32) -> f32 {
    let mut regs = [0f32; super::program::MAX_REGION_OPS];
    let read = |s: ScalarSrc, regs: &[f32]| -> f32 {
        match s {
            ScalarSrc::Acc => acc,
            ScalarSrc::X => x,
            ScalarSrc::Const(c) => p.consts[c as usize],
            ScalarSrc::Reg(r) => regs[r as usize],
        }
    };
    for (ri, op) in p.ops.iter().enumerate() {
        let v = match op.b {
            None => ew1(op.op, read(op.a, &regs)),
            Some(b) => ew2(op.op, read(op.a, &regs), read(b, &regs)),
        };
        regs[ri] = v;
    }
    read(p.result, &regs)
}

/// Flat-ascending reduce through the region kernel (bit-identical order
/// to the reference evaluator).
pub(crate) fn reduce(data: &[f32], init: f32, map: &[u32], region: &RegionFn, out: &mut [f32]) {
    out.fill(init);
    match region {
        RegionFn::Add => {
            for (&x, &of) in data.iter().zip(map) {
                out[of as usize] += x;
            }
        }
        RegionFn::Mul => {
            for (&x, &of) in data.iter().zip(map) {
                out[of as usize] *= x;
            }
        }
        RegionFn::Max => {
            for (&x, &of) in data.iter().zip(map) {
                let o = &mut out[of as usize];
                *o = o.max(x);
            }
        }
        RegionFn::Min => {
            for (&x, &of) in data.iter().zip(map) {
                let o = &mut out[of as usize];
                *o = o.min(x);
            }
        }
        RegionFn::Program(p) => {
            for (&x, &of) in data.iter().zip(map) {
                let o = &mut out[of as usize];
                *o = region_apply(p, *o, x);
            }
        }
    }
}
