//! The pre-PR tree-walk evaluator, retained as the correctness baseline.
//!
//! This is the PR 3 interpreter unchanged in semantics: per-instruction
//! `Value` allocation, `f64`-boxed element access for structural ops,
//! per-element coordinate decoding, per-element region re-evaluation for
//! reduce (beyond the one-op fast path), and platform-libm transcendental
//! math.  It exists for two purposes:
//!
//! * the **differential suite** (rust/tests/differential_interp.rs)
//!   replays every fixture entry plus randomized inputs through both this
//!   path and the compiled register program, under a 1e-6 tolerance (the
//!   compiled path swaps libm for [`super::fmath`], so the two agree to
//!   ~1 ulp rather than bitwise);
//! * the **perf baseline**: `cargo bench --bench perf_interp` measures the
//!   compiled path's speedup against this evaluator in the same process
//!   and records it in BENCH_4.json.
//!
//! Do not optimize this module — its cost profile IS the baseline.

use super::parse::{
    coords_of, declared_dense, elements, err, strides, Attrs, Computation, ConstPayload,
    ConstValue, DType, Module, Shape, ShapeSpec,
};
use crate::{Data, Literal, Result};

// ------------------------------------------------------------------ values

#[derive(Clone, Debug)]
enum Buf {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Pred(Vec<bool>),
}

impl Buf {
    fn len(&self) -> usize {
        match self {
            Buf::F32(v) => v.len(),
            Buf::I32(v) => v.len(),
            Buf::Pred(v) => v.len(),
        }
    }

    fn dtype(&self) -> DType {
        match self {
            Buf::F32(_) => DType::F32,
            Buf::I32(_) => DType::S32,
            Buf::Pred(_) => DType::Pred,
        }
    }

    /// Lossless-for-our-dtypes scalar view (f32 and i32 embed exactly in
    /// f64; pred maps to 0/1) — used by structural ops only, which write
    /// the values straight back into the same dtype.
    fn get_f64(&self, i: usize) -> f64 {
        match self {
            Buf::F32(v) => v[i] as f64,
            Buf::I32(v) => v[i] as f64,
            Buf::Pred(v) => {
                if v[i] {
                    1.0
                } else {
                    0.0
                }
            }
        }
    }

    fn build(dtype: DType, vals: Vec<f64>) -> Buf {
        match dtype {
            DType::F32 => Buf::F32(vals.into_iter().map(|v| v as f32).collect()),
            DType::S32 => Buf::I32(vals.into_iter().map(|v| v as i32).collect()),
            DType::Pred => Buf::Pred(vals.into_iter().map(|v| v != 0.0).collect()),
        }
    }
}

#[derive(Clone, Debug)]
enum Value {
    Dense { dims: Vec<usize>, buf: Buf },
    Tuple(Vec<Value>),
}

impl Value {
    fn dense(&self) -> Result<(&[usize], &Buf)> {
        match self {
            Value::Dense { dims, buf } => Ok((dims, buf)),
            Value::Tuple(_) => Err(err("expected a dense (non-tuple) value".into())),
        }
    }

    fn f32s(&self) -> Result<&[f32]> {
        match self.dense()?.1 {
            Buf::F32(v) => Ok(v),
            other => Err(err(format!("expected f32 data, got {}", other.dtype()))),
        }
    }

    fn preds(&self) -> Result<&[bool]> {
        match self.dense()?.1 {
            Buf::Pred(v) => Ok(v),
            other => Err(err(format!("expected pred data, got {}", other.dtype()))),
        }
    }

    fn scalar_f32(&self) -> Result<f32> {
        let v = self.f32s()?;
        if v.len() != 1 {
            return Err(err(format!("expected a scalar, got {} elements", v.len())));
        }
        Ok(v[0])
    }

    fn from_const(c: &ConstValue) -> Value {
        let buf = match &c.payload {
            ConstPayload::F32(v) => Buf::F32(v.clone()),
            ConstPayload::I32(v) => Buf::I32(v.clone()),
            ConstPayload::Pred(v) => Buf::Pred(v.clone()),
        };
        Value::Dense {
            dims: c.dims.clone(),
            buf,
        }
    }
}

// ------------------------------------------------------------- evaluation

/// Execute the entry computation over argument literals (the pre-PR
/// `Module::evaluate`).
pub(crate) fn evaluate(module: &Module, args: &[&Literal]) -> Result<Literal> {
    let comp = module.entry_computation();
    if args.len() != comp.params.len() {
        return Err(err(format!(
            "entry {:?} takes {} parameters, got {} arguments",
            comp.name,
            comp.params.len(),
            args.len()
        )));
    }
    let mut vals = Vec::with_capacity(args.len());
    for (i, lit) in args.iter().enumerate() {
        let v = value_from_literal(lit)?;
        let pins = &comp.instrs[comp.params[i]];
        if let ShapeSpec::Dense(want) = &pins.shape {
            let (dims, buf) = v.dense()?;
            if dims != want.dims.as_slice() || buf.dtype() != want.dtype {
                return Err(err(format!(
                    "argument {i} ({}): expected {want}, got {}[{}]",
                    pins.name,
                    buf.dtype(),
                    dims.iter()
                        .map(|d| d.to_string())
                        .collect::<Vec<_>>()
                        .join(",")
                )));
            }
        }
        vals.push(v);
    }
    let out = eval_computation(module, comp, &vals)?;
    literal_from_value(out)
}

fn eval_computation(module: &Module, comp: &Computation, args: &[Value]) -> Result<Value> {
    let mut env: Vec<Option<Value>> = vec![None; comp.instrs.len()];
    for idx in 0..comp.instrs.len() {
        let v = eval_instr(module, comp, idx, &env, args)?;
        env[idx] = Some(v);
    }
    Ok(env[comp.root].take().expect("root evaluated"))
}

fn eval_instr(
    module: &Module,
    comp: &Computation,
    idx: usize,
    env: &[Option<Value>],
    args: &[Value],
) -> Result<Value> {
    let ins = &comp.instrs[idx];
    let opv = |i: usize| -> Result<&Value> {
        let oi = *ins
            .operands
            .get(i)
            .ok_or_else(|| err(format!("{}: missing operand {i}", ins.name)))?;
        env[oi]
            .as_ref()
            .ok_or_else(|| err(format!("{}: operand used before definition", ins.name)))
    };
    let out = match ins.op.as_str() {
        "parameter" => {
            let p = ins.param.expect("parameter number");
            args.get(p)
                .ok_or_else(|| {
                    err(format!(
                        "{}: parameter({p}) exceeds the {} arguments supplied",
                        ins.name,
                        args.len()
                    ))
                })?
                .clone()
        }
        "constant" => Value::from_const(ins.literal.as_ref().expect("parsed constant")),
        "add" | "subtract" | "multiply" | "divide" | "maximum" | "minimum" | "power"
        | "remainder" | "and" | "or" | "xor" => binary_elementwise(&ins.op, opv(0)?, opv(1)?)?,
        "abs" | "negate" | "exponential" | "exponential-minus-one" | "log" | "log-plus-one"
        | "logistic" | "tanh" | "sqrt" | "rsqrt" | "sign" | "floor" | "ceil" | "cosine"
        | "sine" | "not" | "copy" => unary_elementwise(&ins.op, opv(0)?)?,
        "compare" => compare(
            ins.attrs
                .direction
                .as_deref()
                .ok_or_else(|| err(format!("{}: compare without direction", ins.name)))?,
            opv(0)?,
            opv(1)?,
        )?,
        "select" => select(opv(0)?, opv(1)?, opv(2)?)?,
        "convert" => convert(opv(0)?, declared_dense(ins)?)?,
        "broadcast" => broadcast(opv(0)?, &ins.attrs.dimensions, declared_dense(ins)?)?,
        "reshape" => reshape(opv(0)?, declared_dense(ins)?)?,
        "transpose" => transpose(opv(0)?, &ins.attrs.dimensions)?,
        "slice" => slice(opv(0)?, &ins.attrs.slice)?,
        "pad" => pad(opv(0)?, opv(1)?, &ins.attrs.padding)?,
        "concatenate" => {
            let mut parts = Vec::with_capacity(ins.operands.len());
            for i in 0..ins.operands.len() {
                parts.push(opv(i)?);
            }
            concatenate(&parts, ins.attrs.dimensions.first().copied().unwrap_or(0))?
        }
        "dot" => dot(opv(0)?, opv(1)?, &ins.attrs)?,
        "reduce" => reduce(module, opv(0)?, opv(1)?, &ins.attrs)?,
        "iota" => iota(declared_dense(ins)?, ins.attrs.iota_dimension.unwrap_or(0))?,
        "reverse" => reverse(opv(0)?, &ins.attrs.dimensions)?,
        "convolution" => convolution(opv(0)?, opv(1)?, &ins.attrs)?,
        "dynamic-slice" => {
            let mut starts = Vec::with_capacity(ins.operands.len().saturating_sub(1));
            for i in 1..ins.operands.len() {
                starts.push(scalar_start(opv(i)?)?);
            }
            dynamic_slice(opv(0)?, &starts, &ins.attrs.dynamic_slice_sizes)?
        }
        "dynamic-update-slice" => {
            let mut starts = Vec::with_capacity(ins.operands.len().saturating_sub(2));
            for i in 2..ins.operands.len() {
                starts.push(scalar_start(opv(i)?)?);
            }
            dynamic_update(opv(0)?, opv(1)?, &starts)?
        }
        "call" => {
            let callee_name = ins
                .attrs
                .to_apply
                .as_deref()
                .ok_or_else(|| err(format!("{}: call without to_apply", ins.name)))?;
            let callee = module.computation(callee_name)?;
            let mut cargs = Vec::with_capacity(ins.operands.len());
            for i in 0..ins.operands.len() {
                cargs.push(opv(i)?.clone());
            }
            eval_computation(module, callee, &cargs)?
        }
        "while" => {
            let cond_name = ins
                .attrs
                .condition
                .as_deref()
                .ok_or_else(|| err(format!("{}: while without condition", ins.name)))?;
            let body_name = ins
                .attrs
                .body
                .as_deref()
                .ok_or_else(|| err(format!("{}: while without body", ins.name)))?;
            let cond = module.computation(cond_name)?;
            let body = module.computation(body_name)?;
            let mut state = opv(0)?.clone();
            loop {
                let c = eval_computation(module, cond, std::slice::from_ref(&state))?;
                let p = c.preds()?;
                if p.len() != 1 {
                    return Err(err(format!(
                        "{}: while condition must produce a scalar pred",
                        ins.name
                    )));
                }
                if !p[0] {
                    break;
                }
                state = eval_computation(module, body, std::slice::from_ref(&state))?;
            }
            state
        }
        "tuple" => {
            let mut parts = Vec::with_capacity(ins.operands.len());
            for i in 0..ins.operands.len() {
                parts.push(opv(i)?.clone());
            }
            Value::Tuple(parts)
        }
        "get-tuple-element" => {
            let i = ins
                .attrs
                .index
                .ok_or_else(|| err(format!("{}: get-tuple-element without index", ins.name)))?;
            match opv(0)? {
                Value::Tuple(parts) => parts
                    .get(i)
                    .cloned()
                    .ok_or_else(|| err(format!("{}: tuple index {i} out of range", ins.name)))?,
                Value::Dense { .. } => {
                    return Err(err(format!("{}: get-tuple-element of non-tuple", ins.name)))
                }
            }
        }
        // Unreachable for modules from Module::parse (its SUPPORTED
        // allow-list screens opcodes); reachable only if that list and
        // these arms drift apart — report it as the bug it is.
        other => {
            return Err(err(format!(
                "opcode {other:?} (instruction {}) passed the parse-time allow-list \
                 but has no evaluator — parse.rs SUPPORTED and reference.rs are out \
                 of sync",
                ins.name
            )))
        }
    };
    // Self-check against the declared result shape: a mismatch means an
    // interpreter bug, better caught here than as silent numerics.
    if let (ShapeSpec::Dense(want), Value::Dense { dims, buf }) = (&ins.shape, &out) {
        if dims != &want.dims || buf.dtype() != want.dtype {
            return Err(err(format!(
                "{}: interpreter produced {}[{}], HLO declares {want}",
                ins.name,
                buf.dtype(),
                dims.iter()
                    .map(|d| d.to_string())
                    .collect::<Vec<_>>()
                    .join(",")
            )));
        }
    }
    Ok(out)
}

fn reduce(module: &Module, data: &Value, init: &Value, attrs: &Attrs) -> Result<Value> {
    let (dims, buf) = data.dense()?;
    let red = &attrs.dimensions;
    let keep: Vec<usize> = (0..dims.len()).filter(|d| !red.contains(d)).collect();
    let out_dims: Vec<usize> = keep.iter().map(|&d| dims[d]).collect();
    let out_elems = elements(&out_dims);
    let comp_name = attrs
        .to_apply
        .as_deref()
        .ok_or_else(|| err("reduce without to_apply".into()))?;
    let comp = module.computation(comp_name)?;
    if comp.params.len() != 2 {
        return Err(err(format!(
            "reduce region {comp_name:?} takes {} parameters, expected 2",
            comp.params.len()
        )));
    }
    let fast = fast_binop(comp);
    let st = strides(dims);
    let out_st = strides(&out_dims);

    match buf {
        Buf::F32(v) => {
            let init = init.scalar_f32()?;
            let mut acc = vec![init; out_elems];
            for (flat, &x) in v.iter().enumerate() {
                let c = coords_of(flat, dims, &st);
                let mut of = 0usize;
                for (k, &d) in keep.iter().enumerate() {
                    of += c[d] * out_st[k];
                }
                acc[of] = match fast {
                    Some("add") => acc[of] + x,
                    Some("multiply") => acc[of] * x,
                    Some("maximum") => acc[of].max(x),
                    Some("minimum") => acc[of].min(x),
                    _ => {
                        let a = Value::Dense {
                            dims: vec![],
                            buf: Buf::F32(vec![acc[of]]),
                        };
                        let b = Value::Dense {
                            dims: vec![],
                            buf: Buf::F32(vec![x]),
                        };
                        eval_computation(module, comp, &[a, b])?.scalar_f32()?
                    }
                };
            }
            Ok(Value::Dense {
                dims: out_dims,
                buf: Buf::F32(acc),
            })
        }
        other => Err(err(format!(
            "reduce over {} is not supported by the interp backend",
            other.dtype()
        ))),
    }
}

/// If `comp` is a single binary op over its two parameters, return the op
/// name (fast-path for reduce regions, which jax emits as one-op adds).
fn fast_binop(comp: &Computation) -> Option<&str> {
    if comp.instrs.len() != 3 || comp.params.len() != 2 {
        return None;
    }
    let root = &comp.instrs[comp.root];
    if root.operands.len() == 2
        && comp.instrs[root.operands[0]].op == "parameter"
        && comp.instrs[root.operands[1]].op == "parameter"
    {
        Some(root.op.as_str())
    } else {
        None
    }
}

// -------------------------------------------------------------- op kernels

fn same_dims<'v>(a: &'v Value, b: &'v Value) -> Result<(&'v [usize], &'v Buf, &'v Buf)> {
    let (da, ba) = a.dense()?;
    let (db, bb) = b.dense()?;
    if da != db {
        return Err(err(format!(
            "shape mismatch in elementwise op: [{}] vs [{}]",
            da.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(","),
            db.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(",")
        )));
    }
    Ok((da, ba, bb))
}

fn binary_elementwise(op: &str, a: &Value, b: &Value) -> Result<Value> {
    let (dims, ba, bb) = same_dims(a, b)?;
    let buf = match (ba, bb) {
        (Buf::F32(x), Buf::F32(y)) => {
            let f: fn(f32, f32) -> f32 = match op {
                "add" => |a, b| a + b,
                "subtract" => |a, b| a - b,
                "multiply" => |a, b| a * b,
                "divide" => |a, b| a / b,
                "maximum" => f32::max,
                "minimum" => f32::min,
                "power" => f32::powf,
                "remainder" => |a, b| a % b,
                _ => return Err(err(format!("op {op:?} not defined for f32"))),
            };
            Buf::F32(x.iter().zip(y).map(|(&a, &b)| f(a, b)).collect())
        }
        (Buf::I32(x), Buf::I32(y)) => {
            let f: fn(i32, i32) -> i32 = match op {
                "add" => i32::wrapping_add,
                "subtract" => i32::wrapping_sub,
                "multiply" => i32::wrapping_mul,
                "maximum" => i32::max,
                "minimum" => i32::min,
                "and" => |a, b| a & b,
                "or" => |a, b| a | b,
                "xor" => |a, b| a ^ b,
                _ => return Err(err(format!("op {op:?} not defined for s32"))),
            };
            Buf::I32(x.iter().zip(y).map(|(&a, &b)| f(a, b)).collect())
        }
        (Buf::Pred(x), Buf::Pred(y)) => {
            let f: fn(bool, bool) -> bool = match op {
                "and" => |a, b| a && b,
                "or" => |a, b| a || b,
                "xor" => |a, b| a ^ b,
                _ => return Err(err(format!("op {op:?} not defined for pred"))),
            };
            Buf::Pred(x.iter().zip(y).map(|(&a, &b)| f(a, b)).collect())
        }
        _ => {
            return Err(err(format!(
                "mixed element types in {op:?}: {} vs {}",
                ba.dtype(),
                bb.dtype()
            )))
        }
    };
    Ok(Value::Dense {
        dims: dims.to_vec(),
        buf,
    })
}

fn unary_elementwise(op: &str, a: &Value) -> Result<Value> {
    let (dims, buf) = a.dense()?;
    let out = match buf {
        Buf::F32(v) => {
            let f: fn(f32) -> f32 = match op {
                "abs" => f32::abs,
                "negate" => |x| -x,
                "exponential" => f32::exp,
                "exponential-minus-one" => f32::exp_m1,
                "log" => f32::ln,
                "log-plus-one" => f32::ln_1p,
                "logistic" => |x| 1.0 / (1.0 + (-x).exp()),
                "tanh" => f32::tanh,
                "sqrt" => f32::sqrt,
                "rsqrt" => |x| 1.0 / x.sqrt(),
                "sign" => |x| {
                    if x == 0.0 {
                        0.0
                    } else {
                        x.signum()
                    }
                },
                "floor" => f32::floor,
                "ceil" => f32::ceil,
                "cosine" => f32::cos,
                "sine" => f32::sin,
                "copy" => |x| x,
                _ => return Err(err(format!("op {op:?} not defined for f32"))),
            };
            Buf::F32(v.iter().map(|&x| f(x)).collect())
        }
        Buf::I32(v) => {
            let f: fn(i32) -> i32 = match op {
                "abs" => i32::wrapping_abs,
                "negate" => i32::wrapping_neg,
                "sign" => i32::signum,
                "copy" => |x| x,
                _ => return Err(err(format!("op {op:?} not defined for s32"))),
            };
            Buf::I32(v.iter().map(|&x| f(x)).collect())
        }
        Buf::Pred(v) => match op {
            "not" => Buf::Pred(v.iter().map(|&x| !x).collect()),
            "copy" => Buf::Pred(v.clone()),
            _ => return Err(err(format!("op {op:?} not defined for pred"))),
        },
    };
    Ok(Value::Dense {
        dims: dims.to_vec(),
        buf: out,
    })
}

fn compare(direction: &str, a: &Value, b: &Value) -> Result<Value> {
    let (dims, ba, bb) = same_dims(a, b)?;
    let n = ba.len();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let ord = match (ba, bb) {
            (Buf::F32(x), Buf::F32(y)) => x[i].partial_cmp(&y[i]),
            (Buf::I32(x), Buf::I32(y)) => Some(x[i].cmp(&y[i])),
            (Buf::Pred(x), Buf::Pred(y)) => Some(x[i].cmp(&y[i])),
            _ => {
                return Err(err(format!(
                    "mixed element types in compare: {} vs {}",
                    ba.dtype(),
                    bb.dtype()
                )))
            }
        };
        // `ord` is None only for NaN: all comparisons false except NE.
        let r = match direction {
            "EQ" => ord == Some(std::cmp::Ordering::Equal),
            "NE" => ord != Some(std::cmp::Ordering::Equal),
            "LT" => ord == Some(std::cmp::Ordering::Less),
            "GT" => ord == Some(std::cmp::Ordering::Greater),
            "LE" => matches!(
                ord,
                Some(std::cmp::Ordering::Less | std::cmp::Ordering::Equal)
            ),
            "GE" => matches!(
                ord,
                Some(std::cmp::Ordering::Greater | std::cmp::Ordering::Equal)
            ),
            other => return Err(err(format!("unknown compare direction {other:?}"))),
        };
        out.push(r);
    }
    Ok(Value::Dense {
        dims: dims.to_vec(),
        buf: Buf::Pred(out),
    })
}

fn select(pred: &Value, on_true: &Value, on_false: &Value) -> Result<Value> {
    let p = pred.preds()?;
    let (dims, bt, bf) = same_dims(on_true, on_false)?;
    let n = bt.len();
    if p.len() != n && p.len() != 1 {
        return Err(err(format!(
            "select predicate has {} elements, operands have {n}",
            p.len()
        )));
    }
    let pick = |i: usize| -> bool {
        if p.len() == 1 {
            p[0]
        } else {
            p[i]
        }
    };
    let buf = match (bt, bf) {
        (Buf::F32(t), Buf::F32(f)) => {
            Buf::F32((0..n).map(|i| if pick(i) { t[i] } else { f[i] }).collect())
        }
        (Buf::I32(t), Buf::I32(f)) => {
            Buf::I32((0..n).map(|i| if pick(i) { t[i] } else { f[i] }).collect())
        }
        (Buf::Pred(t), Buf::Pred(f)) => {
            Buf::Pred((0..n).map(|i| if pick(i) { t[i] } else { f[i] }).collect())
        }
        _ => return Err(err("mixed element types in select".into())),
    };
    Ok(Value::Dense {
        dims: dims.to_vec(),
        buf,
    })
}

fn convert(a: &Value, want: &Shape) -> Result<Value> {
    let (dims, buf) = a.dense()?;
    let n = buf.len();
    let out = match (buf, want.dtype) {
        (Buf::F32(v), DType::F32) => Buf::F32(v.clone()),
        (Buf::I32(v), DType::S32) => Buf::I32(v.clone()),
        (Buf::Pred(v), DType::Pred) => Buf::Pred(v.clone()),
        (Buf::Pred(v), DType::F32) => {
            Buf::F32(v.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect())
        }
        (Buf::Pred(v), DType::S32) => Buf::I32(v.iter().map(|&b| b as i32).collect()),
        (Buf::I32(v), DType::F32) => Buf::F32(v.iter().map(|&x| x as f32).collect()),
        (Buf::F32(v), DType::S32) => {
            // XLA convert f32->s32 rounds toward zero.
            Buf::I32(v.iter().map(|&x| x as i32).collect())
        }
        (Buf::F32(v), DType::Pred) => Buf::Pred(v.iter().map(|&x| x != 0.0).collect()),
        (Buf::I32(v), DType::Pred) => Buf::Pred(v.iter().map(|&x| x != 0).collect()),
    };
    debug_assert_eq!(out.len(), n);
    Ok(Value::Dense {
        dims: dims.to_vec(),
        buf: out,
    })
}

fn broadcast(a: &Value, mapping: &[usize], want: &Shape) -> Result<Value> {
    let (in_dims, buf) = a.dense()?;
    if mapping.len() != in_dims.len() {
        return Err(err(format!(
            "broadcast dimensions {:?} do not cover operand rank {}",
            mapping,
            in_dims.len()
        )));
    }
    for (i, &od) in mapping.iter().enumerate() {
        // A mapped dim must match the output dim or be degenerate (1).
        if od >= want.dims.len() || (want.dims[od] != in_dims[i] && in_dims[i] != 1) {
            return Err(err(format!(
                "broadcast maps operand dim {i} (size {}) to output dim {od} of {want}",
                in_dims[i]
            )));
        }
    }
    let out_dims = want.dims.clone();
    let out_elems = elements(&out_dims);
    let out_st = strides(&out_dims);
    let in_st = strides(in_dims);
    let mut vals = Vec::with_capacity(out_elems);
    for flat in 0..out_elems {
        let c = coords_of(flat, &out_dims, &out_st);
        let mut inf = 0usize;
        for (i, &od) in mapping.iter().enumerate() {
            let ci = if in_dims[i] == 1 { 0 } else { c[od] };
            inf += ci * in_st[i];
        }
        vals.push(buf.get_f64(inf));
    }
    Ok(Value::Dense {
        dims: out_dims,
        buf: Buf::build(buf.dtype(), vals),
    })
}

fn reshape(a: &Value, want: &Shape) -> Result<Value> {
    let (in_dims, buf) = a.dense()?;
    if elements(in_dims) != want.elements() {
        return Err(err(format!(
            "reshape element count mismatch: {} -> {want}",
            elements(in_dims)
        )));
    }
    Ok(Value::Dense {
        dims: want.dims.clone(),
        buf: buf.clone(),
    })
}

fn transpose(a: &Value, perm: &[usize]) -> Result<Value> {
    let (in_dims, buf) = a.dense()?;
    if perm.len() != in_dims.len() || perm.iter().any(|&p| p >= in_dims.len()) {
        return Err(err(format!(
            "transpose permutation {:?} is not a permutation of rank {}",
            perm,
            in_dims.len()
        )));
    }
    let out_dims: Vec<usize> = perm.iter().map(|&p| in_dims[p]).collect();
    let out_st = strides(&out_dims);
    let in_st = strides(in_dims);
    let n = elements(&out_dims);
    let mut vals = Vec::with_capacity(n);
    for flat in 0..n {
        let c = coords_of(flat, &out_dims, &out_st);
        let mut inf = 0usize;
        for (i, &p) in perm.iter().enumerate() {
            inf += c[i] * in_st[p];
        }
        vals.push(buf.get_f64(inf));
    }
    Ok(Value::Dense {
        dims: out_dims,
        buf: Buf::build(buf.dtype(), vals),
    })
}

fn slice(a: &Value, spec: &[(i64, i64, i64)]) -> Result<Value> {
    let (in_dims, buf) = a.dense()?;
    if spec.len() != in_dims.len() {
        return Err(err(format!(
            "slice spec rank {} does not match operand rank {}",
            spec.len(),
            in_dims.len()
        )));
    }
    let mut out_dims = Vec::with_capacity(spec.len());
    for (d, &(start, limit, stride)) in spec.iter().enumerate() {
        if stride <= 0 || start < 0 || limit < start || limit as usize > in_dims[d] {
            return Err(err(format!(
                "invalid slice [{start}:{limit}:{stride}] for dimension of size {}",
                in_dims[d]
            )));
        }
        out_dims.push(((limit - start) as usize).div_ceil(stride as usize));
    }
    let out_st = strides(&out_dims);
    let in_st = strides(in_dims);
    let n = elements(&out_dims);
    let mut vals = Vec::with_capacity(n);
    for flat in 0..n {
        let c = coords_of(flat, &out_dims, &out_st);
        let mut inf = 0usize;
        for (d, &(start, _, stride)) in spec.iter().enumerate() {
            inf += (start as usize + c[d] * stride as usize) * in_st[d];
        }
        vals.push(buf.get_f64(inf));
    }
    Ok(Value::Dense {
        dims: out_dims,
        buf: Buf::build(buf.dtype(), vals),
    })
}

fn pad(a: &Value, fill: &Value, spec: &[(i64, i64, i64)]) -> Result<Value> {
    let (in_dims, buf) = a.dense()?;
    let (fdims, fbuf) = fill.dense()?;
    if !fdims.is_empty() || fbuf.len() != 1 {
        return Err(err("pad fill value must be a scalar".into()));
    }
    if spec.len() != in_dims.len() {
        return Err(err(format!(
            "padding spec rank {} does not match operand rank {}",
            spec.len(),
            in_dims.len()
        )));
    }
    let mut out_dims = Vec::with_capacity(spec.len());
    for (d, &(lo, hi, interior)) in spec.iter().enumerate() {
        if interior < 0 {
            return Err(err("negative interior padding".into()));
        }
        let n = in_dims[d] as i64;
        let stretched = if n == 0 { 0 } else { n + (n - 1) * interior };
        let total = lo + stretched + hi;
        if total < 0 {
            return Err(err(format!("padding {lo}_{hi} collapses dimension {d}")));
        }
        out_dims.push(total as usize);
    }
    let out_elems = elements(&out_dims);
    let fill_v = fbuf.get_f64(0);
    let mut vals = vec![fill_v; out_elems];
    let in_st = strides(in_dims);
    let out_st = strides(&out_dims);
    let in_elems = elements(in_dims);
    'next: for flat in 0..in_elems {
        let c = coords_of(flat, in_dims, &in_st);
        let mut of = 0usize;
        for (d, &(lo, _, interior)) in spec.iter().enumerate() {
            let pos = lo + c[d] as i64 * (1 + interior);
            if pos < 0 || pos as usize >= out_dims[d] {
                continue 'next; // cropped away by negative padding
            }
            of += pos as usize * out_st[d];
        }
        vals[of] = buf.get_f64(flat);
    }
    Ok(Value::Dense {
        dims: out_dims,
        buf: Buf::build(buf.dtype(), vals),
    })
}

fn concatenate(parts: &[&Value], dim: usize) -> Result<Value> {
    if parts.is_empty() {
        return Err(err("concatenate with no operands".into()));
    }
    let (d0, b0) = parts[0].dense()?;
    if dim >= d0.len() {
        return Err(err(format!(
            "concatenate dimension {dim} out of range for rank {}",
            d0.len()
        )));
    }
    let dtype = b0.dtype();
    let mut out_dims = d0.to_vec();
    out_dims[dim] = 0;
    for p in parts {
        let (d, b) = p.dense()?;
        if d.len() != d0.len() || b.dtype() != dtype {
            return Err(err("concatenate operand shape/type mismatch".into()));
        }
        out_dims[dim] += d[dim];
    }
    let out_st = strides(&out_dims);
    let n = elements(&out_dims);
    let mut vals = Vec::with_capacity(n);
    for flat in 0..n {
        let mut c = coords_of(flat, &out_dims, &out_st);
        let mut k = c[dim];
        let mut src = None;
        for p in parts {
            let (d, b) = p.dense()?;
            if k < d[dim] {
                c[dim] = k;
                let st = strides(d);
                let inf: usize = c.iter().zip(&st).map(|(&ci, &si)| ci * si).sum();
                src = Some(b.get_f64(inf));
                break;
            }
            k -= d[dim];
        }
        vals.push(src.expect("concatenate source found"));
    }
    Ok(Value::Dense {
        dims: out_dims,
        buf: Buf::build(dtype, vals),
    })
}

fn dot(a: &Value, b: &Value, attrs: &Attrs) -> Result<Value> {
    if attrs.lhs_contracting.len() != 1 || attrs.rhs_contracting.len() != 1 {
        return Err(err(
            "dot requires exactly one contracting dimension per side".into(),
        ));
    }
    let (lc, rc) = (attrs.lhs_contracting[0], attrs.rhs_contracting[0]);
    let la = a.f32s()?;
    let rb = b.f32s()?;
    let (ld, _) = a.dense()?;
    let (rd, _) = b.dense()?;
    if lc >= ld.len() || rc >= rd.len() || ld[lc] != rd[rc] {
        return Err(err(format!(
            "dot contraction mismatch: lhs dim {lc} of {ld:?} vs rhs dim {rc} of {rd:?}"
        )));
    }
    let lbd = &attrs.lhs_batch;
    let rbd = &attrs.rhs_batch;
    if lbd.len() != rbd.len() {
        return Err(err("dot batch dimension ranks disagree".into()));
    }
    for (&x, &y) in lbd.iter().zip(rbd.iter()) {
        if x >= ld.len() || y >= rd.len() || ld[x] != rd[y] || x == lc || y == rc {
            return Err(err(format!(
                "dot batch dimension mismatch: lhs dim {x} of {ld:?} vs rhs dim {y} of {rd:?}"
            )));
        }
    }
    let k = ld[lc];
    let lfree: Vec<usize> = (0..ld.len())
        .filter(|&d| d != lc && !lbd.contains(&d))
        .collect();
    let rfree: Vec<usize> = (0..rd.len())
        .filter(|&d| d != rc && !rbd.contains(&d))
        .collect();
    // XLA layout: batch dims (lhs order), then lhs free, then rhs free.
    let out_dims: Vec<usize> = lbd
        .iter()
        .map(|&d| ld[d])
        .chain(lfree.iter().map(|&d| ld[d]))
        .chain(rfree.iter().map(|&d| rd[d]))
        .collect();
    let l_st = strides(ld);
    let r_st = strides(rd);
    let out_st = strides(&out_dims);
    let n = elements(&out_dims);
    let mut out = Vec::with_capacity(n);
    for flat in 0..n {
        let c = coords_of(flat, &out_dims, &out_st);
        let mut lbase = 0usize;
        let mut rbase = 0usize;
        for (i, (&x, &y)) in lbd.iter().zip(rbd.iter()).enumerate() {
            lbase += c[i] * l_st[x];
            rbase += c[i] * r_st[y];
        }
        for (i, &d) in lfree.iter().enumerate() {
            lbase += c[lbd.len() + i] * l_st[d];
        }
        for (i, &d) in rfree.iter().enumerate() {
            rbase += c[lbd.len() + lfree.len() + i] * r_st[d];
        }
        let mut acc = 0.0f32;
        for kk in 0..k {
            acc += la[lbase + kk * l_st[lc]] * rb[rbase + kk * r_st[rc]];
        }
        out.push(acc);
    }
    Ok(Value::Dense {
        dims: out_dims,
        buf: Buf::F32(out),
    })
}

fn iota(want: &Shape, dim: usize) -> Result<Value> {
    if dim >= want.dims.len().max(1) {
        return Err(err(format!("iota dimension {dim} out of range for {want}")));
    }
    let st = strides(&want.dims);
    let n = want.elements();
    let mut vals = Vec::with_capacity(n);
    for flat in 0..n {
        let c = coords_of(flat, &want.dims, &st);
        vals.push(c.get(dim).copied().unwrap_or(0) as f64);
    }
    Ok(Value::Dense {
        dims: want.dims.clone(),
        buf: Buf::build(want.dtype, vals),
    })
}

fn reverse(a: &Value, rev: &[usize]) -> Result<Value> {
    let (dims, buf) = a.dense()?;
    if rev.iter().any(|&d| d >= dims.len()) {
        return Err(err(format!(
            "reverse dimensions {rev:?} out of range for rank {}",
            dims.len()
        )));
    }
    let st = strides(dims);
    let n = elements(dims);
    let mut vals = Vec::with_capacity(n);
    for flat in 0..n {
        let mut c = coords_of(flat, dims, &st);
        for &d in rev {
            c[d] = dims[d] - 1 - c[d];
        }
        let inf: usize = c.iter().zip(&st).map(|(&ci, &si)| ci * si).sum();
        vals.push(buf.get_f64(inf));
    }
    Ok(Value::Dense {
        dims: dims.to_vec(),
        buf: Buf::build(buf.dtype(), vals),
    })
}

fn scalar_start(v: &Value) -> Result<i64> {
    match v.dense()?.1 {
        Buf::I32(x) if x.len() == 1 => Ok(i64::from(x[0])),
        other => Err(err(format!(
            "dynamic start index must be a scalar s32, got {}[{}]",
            other.dtype(),
            other.len()
        ))),
    }
}

fn dynamic_slice(a: &Value, starts: &[i64], sizes: &[usize]) -> Result<Value> {
    let (dims, buf) = a.dense()?;
    if starts.len() != dims.len() || sizes.len() != dims.len() {
        return Err(err(format!(
            "dynamic-slice expects {} start indices and sizes, got {} and {}",
            dims.len(),
            starts.len(),
            sizes.len()
        )));
    }
    let mut offs = Vec::with_capacity(dims.len());
    for (d, (&sz, &start)) in sizes.iter().zip(starts).enumerate() {
        if sz > dims[d] {
            return Err(err(format!(
                "dynamic-slice size {sz} exceeds dimension {d} of size {}",
                dims[d]
            )));
        }
        // The HLO contract: starts clamp to [0, dim - size].
        offs.push(start.clamp(0, (dims[d] - sz) as i64) as usize);
    }
    let st = strides(dims);
    let out_st = strides(sizes);
    let n = elements(sizes);
    let mut vals = Vec::with_capacity(n);
    for flat in 0..n {
        let c = coords_of(flat, sizes, &out_st);
        let inf: usize = c
            .iter()
            .enumerate()
            .map(|(d, &ci)| (offs[d] + ci) * st[d])
            .sum();
        vals.push(buf.get_f64(inf));
    }
    Ok(Value::Dense {
        dims: sizes.to_vec(),
        buf: Buf::build(buf.dtype(), vals),
    })
}

fn dynamic_update(a: &Value, u: &Value, starts: &[i64]) -> Result<Value> {
    let (dims, buf) = a.dense()?;
    let (udims, ubuf) = u.dense()?;
    if ubuf.dtype() != buf.dtype() {
        return Err(err(format!(
            "dynamic-update-slice update dtype {} does not match operand dtype {}",
            ubuf.dtype(),
            buf.dtype()
        )));
    }
    if starts.len() != dims.len() || udims.len() != dims.len() {
        return Err(err(format!(
            "dynamic-update-slice expects {} start indices and an update of the same \
             rank, got {} and rank {}",
            dims.len(),
            starts.len(),
            udims.len()
        )));
    }
    let mut offs = Vec::with_capacity(dims.len());
    for (d, (&ud, &start)) in udims.iter().zip(starts).enumerate() {
        if ud > dims[d] {
            return Err(err(format!(
                "dynamic-update-slice update dim {d} of size {ud} exceeds operand \
                 dimension of size {}",
                dims[d]
            )));
        }
        offs.push(start.clamp(0, (dims[d] - ud) as i64) as usize);
    }
    let n = elements(dims);
    let mut vals: Vec<f64> = (0..n).map(|i| buf.get_f64(i)).collect();
    let st = strides(dims);
    let ust = strides(udims);
    for flat in 0..elements(udims) {
        let c = coords_of(flat, udims, &ust);
        let of: usize = c
            .iter()
            .enumerate()
            .map(|(d, &ci)| (offs[d] + ci) * st[d])
            .sum();
        vals[of] = ubuf.get_f64(flat);
    }
    Ok(Value::Dense {
        dims: dims.to_vec(),
        buf: Buf::build(buf.dtype(), vals),
    })
}

/// Dimension positions of one `dim_labels` segment: batch/feature (or
/// input/output feature for the kernel segment) plus spatial positions in
/// spatial-number order.
fn conv_order(seg: &str, bc: char, fc: char) -> Result<(usize, usize, Vec<usize>)> {
    let mut b = None;
    let mut f = None;
    let mut sp: Vec<(usize, usize)> = Vec::new();
    for (pos, ch) in seg.chars().enumerate() {
        if ch == bc {
            b = Some(pos);
        } else if ch == fc {
            f = Some(pos);
        } else if let Some(d) = ch.to_digit(10) {
            sp.push((d as usize, pos));
        } else {
            return Err(err(format!(
                "unknown character {ch:?} in convolution dim_labels segment {seg:?}"
            )));
        }
    }
    sp.sort_unstable();
    let spatial = sp.into_iter().map(|(_, p)| p).collect();
    let b = b.ok_or_else(|| err(format!("dim_labels segment {seg:?} lacks {bc:?}")))?;
    let f = f.ok_or_else(|| err(format!("dim_labels segment {seg:?} lacks {fc:?}")))?;
    Ok((b, f, spatial))
}

/// Direct convolution in plain accumulation order — deliberately a
/// different algorithm from both compiled strategies (the im2col-onto-dot
/// path and the fused blocked kernel, which share the pinned-lanes patch
/// K order), so the differential suite cross-checks the lowerings rather
/// than replaying them.
fn convolution(a: &Value, b: &Value, attrs: &Attrs) -> Result<Value> {
    let labels = attrs
        .dim_labels
        .as_deref()
        .ok_or_else(|| err("convolution without dim_labels".into()))?;
    let (in_seg, rest) = labels
        .split_once('_')
        .ok_or_else(|| err(format!("bad convolution dim_labels {labels:?}")))?;
    let (ker_seg, out_seg) = rest
        .split_once("->")
        .ok_or_else(|| err(format!("bad convolution dim_labels {labels:?}")))?;
    let (in_b, in_f, in_sp) = conv_order(in_seg, 'b', 'f')?;
    let (ker_i, ker_o, ker_sp) = conv_order(ker_seg, 'i', 'o')?;
    let (out_b, out_f, out_sp) = conv_order(out_seg, 'b', 'f')?;

    let lhs = a.f32s()?;
    let ker = b.f32s()?;
    let (ld, _) = a.dense()?;
    let (rd, _) = b.dense()?;
    let srank = in_sp.len();
    let window = &attrs.window;
    if window.len() != srank || ker_sp.len() != srank || out_sp.len() != srank {
        return Err(err(format!(
            "convolution window rank {} does not match spatial rank {srank}",
            window.len()
        )));
    }
    if attrs.batch_group_count.unwrap_or(1) != 1 {
        return Err(err("convolution batch_group_count > 1 is not supported".into()));
    }
    let groups = attrs.feature_group_count.unwrap_or(1);
    let (batch, ci) = (ld[in_b], ld[in_f]);
    let (ki, ko) = (rd[ker_i], rd[ker_o]);
    if groups == 0 || ci != groups * ki || ko % groups != 0 {
        return Err(err(format!(
            "convolution feature grouping mismatch: input features {ci}, kernel input \
             features {ki}, groups {groups}, output features {ko}"
        )));
    }
    let ng = ko / groups;

    let mut out_dims = vec![0usize; srank + 2];
    out_dims[out_b] = batch;
    out_dims[out_f] = ko;
    for d in 0..srank {
        let w = &window[d];
        if w.base_dilation == 0 {
            return Err(err("convolution base_dilation 0".into()));
        }
        if w.size != rd[ker_sp[d]] {
            return Err(err(format!(
                "convolution window size {} does not match kernel dimension {}",
                w.size,
                rd[ker_sp[d]]
            )));
        }
        // lhs_dilate (transposed convolution): spatial extent of the
        // virtually interior-dilated input.
        let dilated = match ld[in_sp[d]] {
            0 => 0,
            n => (n - 1) * w.base_dilation + 1,
        };
        let padded = dilated as i64 + w.pad_lo + w.pad_hi;
        let extent = (w.window_dilation * (w.size - 1) + 1) as i64;
        if w.stride == 0 || padded < extent {
            return Err(err(format!(
                "convolution window does not fit dimension {d} (padded {padded}, \
                 extent {extent})"
            )));
        }
        out_dims[out_sp[d]] = ((padded - extent) / w.stride as i64 + 1) as usize;
    }

    let l_st = strides(ld);
    let r_st = strides(rd);
    let out_st = strides(&out_dims);
    let n = elements(&out_dims);
    let ker_dims: Vec<usize> = window.iter().map(|w| w.size).collect();
    let ker_elems = elements(&ker_dims);
    let ker_st = strides(&ker_dims);
    let mut out = Vec::with_capacity(n);
    for flat in 0..n {
        let c = coords_of(flat, &out_dims, &out_st);
        let of = c[out_f];
        let g = of / ng;
        let mut acc = 0.0f32;
        for kflat in 0..ker_elems {
            let kc = coords_of(kflat, &ker_dims, &ker_st);
            let mut lbase = c[out_b] * l_st[in_b];
            let mut in_range = true;
            for d in 0..srank {
                let w = &window[d];
                // Position in the lhs-dilated coordinate system; only
                // multiples of base_dilation hit a real input tap.
                let iy = c[out_sp[d]] as i64 * w.stride as i64 - w.pad_lo
                    + kc[d] as i64 * w.window_dilation as i64;
                let base = w.base_dilation as i64;
                if iy < 0 || iy % base != 0 || (iy / base) as usize >= ld[in_sp[d]] {
                    in_range = false;
                    break;
                }
                lbase += (iy / base) as usize * l_st[in_sp[d]];
            }
            if !in_range {
                continue;
            }
            let mut rbase = of * r_st[ker_o];
            for d in 0..srank {
                rbase += kc[d] * r_st[ker_sp[d]];
            }
            for ic in 0..ki {
                acc += lhs[lbase + (g * ki + ic) * l_st[in_f]] * ker[rbase + ic * r_st[ker_i]];
            }
        }
        out.push(acc);
    }
    Ok(Value::Dense {
        dims: out_dims,
        buf: Buf::F32(out),
    })
}

// ----------------------------------------------------- literal conversion

fn value_from_literal(l: &Literal) -> Result<Value> {
    let (data, dims) = l
        .dense_parts()
        .ok_or_else(|| err("tuple arguments are not supported".into()))?;
    let mut ud = Vec::with_capacity(dims.len());
    for &d in dims {
        if d < 0 {
            return Err(err(format!("negative dimension {d} in argument")));
        }
        ud.push(d as usize);
    }
    let buf = match data {
        Data::F32(v) => Buf::F32(v.clone()),
        Data::I32(v) => Buf::I32(v.clone()),
    };
    if buf.len() != elements(&ud) {
        return Err(err(format!(
            "argument has {} elements but dims {ud:?}",
            buf.len()
        )));
    }
    Ok(Value::Dense { dims: ud, buf })
}

fn literal_from_value(v: Value) -> Result<Literal> {
    match v {
        Value::Dense { dims, buf } => {
            let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            let data = match buf {
                Buf::F32(v) => Data::F32(v),
                Buf::I32(v) => Data::I32(v),
                Buf::Pred(v) => Data::I32(v.into_iter().map(i32::from).collect()),
            };
            Ok(Literal::from_data(data, dims))
        }
        Value::Tuple(parts) => {
            let mut out = Vec::with_capacity(parts.len());
            for p in parts {
                out.push(literal_from_value(p)?);
            }
            Ok(Literal::tuple(out))
        }
    }
}
