//! Compile/link **stub** of the `xla` (xla_extension) PJRT bindings.
//!
//! The real dependency is the Rust binding over `xla_extension` 0.5.1
//! (PJRT CPU client + HLO-text compilation; see `/opt/xla-example` on the
//! AOT build machine and `python/compile/aot.py`).  That native library is
//! not vendorable into this repository, so this crate provides the exact
//! API surface `divebatch::runtime` consumes with the same signatures and
//! ownership rules — every type is plain data and therefore `Send + Sync`,
//! which is what lets the runtime layer be shared across trial-engine
//! worker threads in unit tests without the native backend.
//!
//! Semantics:
//!
//! * Parsing ([`HloModuleProto::from_text_file`]) and compilation
//!   ([`PjRtClient::compile`]) **succeed** — they read and retain the HLO
//!   text, so the compile-cache (hit/miss, compile-once-per-entry under
//!   concurrency, stats accounting) is fully exercisable without XLA.
//! * Execution ([`PjRtLoadedExecutable::execute`]) **fails** with a clear
//!   [`Error::StubBackend`] — the stub cannot evaluate HLO.  Integration
//!   tests that need real numerics detect this via
//!   `Runtime::has_execution_backend()` (the client reports platform
//!   [`STUB_PLATFORM`]) and skip.
//!
//! Swapping in the real backend is a one-line change in
//! `rust/Cargo.toml`: point the `xla` dependency at the real binding
//! instead of `vendor/xla`.  No source file outside that manifest refers
//! to this crate being a stub except through `platform_name()`.

use std::borrow::Borrow;
use std::fmt;
use std::sync::Arc;

/// Platform name reported by the stub client; the runtime uses this to
/// detect that execution is unavailable.
pub const STUB_PLATFORM: &str = "stub";

/// Error type mirroring the real binding's (anyhow-compatible: it is a
/// `std::error::Error` and `Send + Sync`).
#[derive(Clone, Debug)]
pub enum Error {
    /// An operation the stub cannot perform (execution).
    StubBackend(String),
    /// File / parse errors from the HLO-text loading path.
    Io(String),
    /// Shape/dtype misuse of a [`Literal`].
    Literal(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::StubBackend(msg) => write!(
                f,
                "xla stub backend: {msg} (link the real xla_extension binding \
                 in rust/Cargo.toml to execute compiled entries)"
            ),
            Error::Io(msg) => write!(f, "xla stub io: {msg}"),
            Error::Literal(msg) => write!(f, "xla stub literal: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can hold (the subset the runtime uses).
pub trait Element: Copy + Send + Sync + 'static {
    fn wrap(v: Vec<Self>) -> Data;
    fn unwrap(d: &Data) -> Option<&[Self]>;
    fn type_name() -> &'static str;
}

/// Typed storage behind a [`Literal`].
#[derive(Clone, Debug)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }
}

impl Element for f32 {
    fn wrap(v: Vec<f32>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Option<&[f32]> {
        match d {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
    fn type_name() -> &'static str {
        "f32"
    }
}

impl Element for i32 {
    fn wrap(v: Vec<i32>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Option<&[i32]> {
        match d {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
    fn type_name() -> &'static str {
        "i32"
    }
}

/// Host-side tensor value (upload argument / fetched result).
#[derive(Clone, Debug)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: Element>(v: &[T]) -> Literal {
        Literal {
            dims: vec![v.len() as i64],
            data: T::wrap(v.to_vec()),
        }
    }

    /// Reinterpret under new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.data.len() {
            return Err(Error::Literal(format!(
                "reshape to {dims:?} ({want} elements) from {} elements",
                self.data.len()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .map(<[T]>::to_vec)
            .ok_or_else(|| Error::Literal(format!("literal is not {}", T::type_name())))
    }

    pub fn get_first_element<T: Element>(&self) -> Result<T> {
        self.to_vec::<T>()?
            .first()
            .copied()
            .ok_or_else(|| Error::Literal("empty literal".into()))
    }

    /// Split a tuple literal into its components.  Stub literals are
    /// never tuples (they only exist on the upload path), so this is
    /// reachable only through an (impossible) stub execution result.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error::StubBackend("decompose_tuple on a stub literal".into()))
    }
}

/// Parsed HLO module (the stub retains the text it was parsed from).
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    text: Arc<String>,
}

impl HloModuleProto {
    /// Read an HLO **text** file (the interchange format emitted by
    /// python/compile/aot.py).  The stub validates readability only.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Io(format!("reading HLO text {path:?}: {e}")))?;
        Ok(HloModuleProto {
            text: Arc::new(text),
        })
    }
}

/// An XLA computation wrapping a parsed module.
#[derive(Clone, Debug)]
pub struct XlaComputation {
    module: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            module: proto.clone(),
        }
    }
}

/// PJRT client handle.  The stub's only state is the platform name it
/// reports; creation never fails.
#[derive(Clone, Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        STUB_PLATFORM.to_string()
    }

    /// "Compile" a computation.  Succeeds so the executable cache is
    /// exercisable; the product refuses to execute.
    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable {
            hlo_bytes: comp.module.text.len(),
        })
    }
}

/// Device buffer handle returned by `execute` (never constructed by the
/// stub; present so caller code type-checks against the real binding).
#[derive(Clone, Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::StubBackend("fetching from a stub buffer".into()))
    }
}

/// A compiled executable handle.
#[derive(Clone, Debug)]
pub struct PjRtLoadedExecutable {
    /// Size of the HLO text this was "compiled" from (debug visibility).
    pub hlo_bytes: usize,
}

impl PjRtLoadedExecutable {
    /// Execution is the one operation the stub cannot provide.
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::StubBackend(
            "cannot execute compiled HLO".into(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_is_send_sync() {
        fn check<T: Send + Sync>() {}
        check::<PjRtClient>();
        check::<PjRtLoadedExecutable>();
        check::<PjRtBuffer>();
        check::<Literal>();
        check::<HloModuleProto>();
        check::<XlaComputation>();
        check::<Error>();
    }

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.get_first_element::<f32>().unwrap(), 1.0);
        assert!(l.to_vec::<i32>().is_err());
        assert!(l.reshape(&[2, 2]).is_ok());
        assert!(l.reshape(&[3, 2]).is_err());
        let i = Literal::vec1(&[7i32]);
        assert_eq!(i.get_first_element::<i32>().unwrap(), 7);
    }

    #[test]
    fn compile_succeeds_execute_fails() {
        let dir = std::env::temp_dir().join(format!("xla-stub-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mod.hlo.txt");
        std::fs::write(&path, "HloModule stub_test").unwrap();

        let proto = HloModuleProto::from_text_file(path.to_str().unwrap()).unwrap();
        let comp = XlaComputation::from_proto(&proto);
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), STUB_PLATFORM);
        let exe = client.compile(&comp).unwrap();
        assert!(exe.hlo_bytes > 0);
        let err = exe.execute::<Literal>(&[]).unwrap_err();
        assert!(err.to_string().contains("stub backend"), "{err}");

        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
