//! Vendored `xla` (xla_extension) PJRT bindings with two in-crate backends.
//!
//! The real dependency is the Rust binding over `xla_extension` 0.5.1
//! (PJRT CPU client + HLO-text compilation; see `/opt/xla-example` on the
//! AOT build machine and `python/compile/aot.py`).  That native library is
//! not vendorable into this repository, so this crate provides the exact
//! API surface `divebatch::runtime` consumes — same signatures, same
//! ownership rules, every type plain data and therefore `Send + Sync` —
//! with the backend selected by `DIVEBATCH_BACKEND` at client creation:
//!
//! * **`interp`** (the default): a pure-Rust HLO-text interpreter
//!   (the `interp` module) with a **compile phase and an execute phase**.
//!   [`PjRtClient::compile`] parses the module (rejecting anything outside
//!   the supported op subset with an error naming the opcode) and lowers
//!   it into a flat SSA register program: typed f32/i32/pred kernels,
//!   precomputed gather maps and dot/reduce plans, fused elementwise
//!   loops, and a last-use buffer arena reused across calls — so
//!   [`PjRtLoadedExecutable::execute`] does near-zero allocation in steady
//!   state and borrows its argument [`Literal`]s rather than cloning them.
//!   Transcendentals use in-crate deterministic kernels (interp/fmath.rs),
//!   so compiled results are bit-identical across platforms.  Compiled
//!   execution runs in one of two tiers ([`InterpTier`]): the default
//!   SIMD tier (8-lane blocked kernels, cost-model-selected dot variants,
//!   AVX where available) and a scalar tier selectable at runtime with
//!   `DIVEBATCH_INTERP_TIER=scalar`.  Both tiers implement the same
//!   pinned 8-lane accumulation contract, so they are bit-identical —
//!   the tier is a pure speed knob (`perf_interp_simd` / BENCH_6.json
//!   gates the win).  Convolutions execute through a per-conv cost-model
//!   choice between a fused blocked-direct kernel (patch tiles gathered
//!   straight through the precomputed im2col map — no patch-matrix
//!   materialization, no conv scratch) and the materializing
//!   im2col-onto-dot fallback; both strategies follow the same contract
//!   and are bit-identical, `DIVEBATCH_CONV_ALGO=blocked|im2col`
//!   overrides the choice, and `perf_conv` / BENCH_7.json gates the
//!   blocked win.  The pre-PR tree-walk evaluator is retained as
//!   [`PjRtLoadedExecutable::execute_reference`] for differential tests
//!   and the `perf_interp` bench baseline (see BENCH_4.json at the repo
//!   root).  This is the backend the numeric test suite runs on
//!   everywhere — no AOT artifacts beyond the committed fixtures, no
//!   native XLA.  Platform name: [`INTERP_PLATFORM`].
//! * **`stub`** (`DIVEBATCH_BACKEND=stub`): compile/link stub.  Parsing
//!   and compilation succeed (the HLO text is retained, so the compile
//!   cache is fully exercisable) but execution fails with a clear
//!   [`Error::StubBackend`].  Platform name: [`STUB_PLATFORM`]; the
//!   runtime's `has_execution_backend()` reports `false` on it.
//!
//! The env var is read once per [`PjRtClient::cpu`] call; tests that need
//! a specific backend regardless of the environment use the explicit
//! [`PjRtClient::interp`] / [`PjRtClient::stub`] constructors instead of
//! racing on process-global env state.
//!
//! Swapping in the **real** backend is a one-line change in
//! `rust/Cargo.toml`: point the `xla` dependency at the real binding
//! instead of `vendor/xla`.  No source file outside that manifest refers
//! to this crate being vendored except through `platform_name()`.

use std::borrow::Borrow;
use std::fmt;
use std::sync::{Arc, OnceLock};

mod interp;

/// Platform name reported by the compile-only stub backend; the runtime
/// uses this to detect that execution is unavailable.
pub const STUB_PLATFORM: &str = "stub";

/// Platform name reported by the pure-Rust HLO interpreter backend.
pub const INTERP_PLATFORM: &str = "interp";

/// Execution tier of the compiled interpreter.
///
/// The tier selects the kernel *strategy*, never the numerics: both tiers
/// implement the same pinned 8-lane accumulation contract (see
/// `interp/kernels.rs`), so results — including canonical run records and
/// the golden byte pin — are identical bit for bit.  `Scalar` exists as a
/// runtime escape hatch (`DIVEBATCH_INTERP_TIER=scalar`) and as the
/// baseline the `perf_interp_simd` bench measures the SIMD tier against.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum InterpTier {
    /// 8-lane blocked kernels, tiled/axpy dot variants, AVX where the CPU
    /// has it (the default).
    #[default]
    Simd,
    /// Plain scalar loops implementing the identical lane contract.
    Scalar,
}

impl InterpTier {
    /// The process-default tier: `DIVEBATCH_INTERP_TIER=scalar` forces
    /// the scalar tier; anything else (including unset) selects SIMD.
    /// Read once and cached — tests and benches that need a specific tier
    /// pass it explicitly instead of racing on process-global env state.
    pub fn from_env() -> InterpTier {
        static TIER: OnceLock<InterpTier> = OnceLock::new();
        *TIER.get_or_init(|| {
            match std::env::var("DIVEBATCH_INTERP_TIER").as_deref() {
                Ok("scalar") => InterpTier::Scalar,
                _ => InterpTier::Simd,
            }
        })
    }
}

/// Error type mirroring the real binding's (anyhow-compatible: it is a
/// `std::error::Error` and `Send + Sync`).
#[derive(Clone, Debug)]
pub enum Error {
    /// An operation the compile-only stub cannot perform (execution).
    StubBackend(String),
    /// File / parse errors from the HLO-text loading path.
    Io(String),
    /// Shape/dtype misuse of a [`Literal`].
    Literal(String),
    /// HLO parse/evaluation errors from the interpreter backend.
    Interp(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::StubBackend(msg) => write!(
                f,
                "xla stub backend: {msg} (use the default interp backend, or link \
                 the real xla_extension binding in rust/Cargo.toml, to execute \
                 compiled entries)"
            ),
            Error::Io(msg) => write!(f, "xla io: {msg}"),
            Error::Literal(msg) => write!(f, "xla literal: {msg}"),
            Error::Interp(msg) => write!(f, "xla interp: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can hold (the subset the runtime uses).
pub trait Element: Copy + Send + Sync + 'static {
    fn wrap(v: Vec<Self>) -> Data;
    fn unwrap(d: &Data) -> Option<&[Self]>;
    fn type_name() -> &'static str;
}

/// Typed storage behind a [`Literal`].
#[derive(Clone, Debug)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }
}

impl Element for f32 {
    fn wrap(v: Vec<f32>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Option<&[f32]> {
        match d {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
    fn type_name() -> &'static str {
        "f32"
    }
}

impl Element for i32 {
    fn wrap(v: Vec<i32>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Option<&[i32]> {
        match d {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
    fn type_name() -> &'static str {
        "i32"
    }
}

/// Host-side tensor value (upload argument / fetched result).  Execution
/// results from the interpreter backend can be **tuples** — split them
/// with [`Literal::decompose_tuple`], exactly like the real binding.
#[derive(Clone, Debug)]
pub struct Literal {
    repr: Repr,
}

#[derive(Clone, Debug)]
enum Repr {
    Dense { data: Data, dims: Vec<i64> },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: Element>(v: &[T]) -> Literal {
        Literal {
            repr: Repr::Dense {
                dims: vec![v.len() as i64],
                data: T::wrap(v.to_vec()),
            },
        }
    }

    pub(crate) fn from_data(data: Data, dims: Vec<i64>) -> Literal {
        Literal {
            repr: Repr::Dense { data, dims },
        }
    }

    pub(crate) fn tuple(parts: Vec<Literal>) -> Literal {
        Literal {
            repr: Repr::Tuple(parts),
        }
    }

    pub(crate) fn dense_parts(&self) -> Option<(&Data, &[i64])> {
        match &self.repr {
            Repr::Dense { data, dims } => Some((data, dims)),
            Repr::Tuple(_) => None,
        }
    }

    /// Reinterpret under new dimensions.  Every dimension must be
    /// non-negative and the element count must match exactly.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let Repr::Dense { data, dims: _ } = &self.repr else {
            return Err(Error::Literal("cannot reshape a tuple literal".into()));
        };
        if dims.iter().any(|&d| d < 0) {
            return Err(Error::Literal(format!(
                "reshape to {dims:?}: negative dimension"
            )));
        }
        let want: i64 = dims.iter().product();
        if want as usize != data.len() {
            return Err(Error::Literal(format!(
                "reshape to {dims:?} ({want} elements) from {} elements",
                data.len()
            )));
        }
        Ok(Literal {
            repr: Repr::Dense {
                data: data.clone(),
                dims: dims.to_vec(),
            },
        })
    }

    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
        let Repr::Dense { data, .. } = &self.repr else {
            return Err(Error::Literal(
                "literal is a tuple (decompose it first)".into(),
            ));
        };
        T::unwrap(data)
            .map(<[T]>::to_vec)
            .ok_or_else(|| Error::Literal(format!("literal is not {}", T::type_name())))
    }

    pub fn get_first_element<T: Element>(&self) -> Result<T> {
        self.to_vec::<T>()?
            .first()
            .copied()
            .ok_or_else(|| Error::Literal("empty literal".into()))
    }

    /// Split a tuple literal into its components (consumes the elements,
    /// like the real binding).
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match &mut self.repr {
            Repr::Tuple(parts) => Ok(std::mem::take(parts)),
            Repr::Dense { .. } => Err(Error::Literal(
                "decompose_tuple on a non-tuple literal".into(),
            )),
        }
    }
}

/// Parsed HLO module (retains the text it was parsed from).
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    text: Arc<String>,
}

impl HloModuleProto {
    /// Read an HLO **text** file (the interchange format emitted by
    /// python/compile/aot.py).  Validates readability only; op-level
    /// validation happens at [`PjRtClient::compile`].
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Io(format!("reading HLO text {path:?}: {e}")))?;
        Ok(HloModuleProto {
            text: Arc::new(text),
        })
    }

    /// Wrap in-memory HLO text.  Like [`HloModuleProto::from_text_file`],
    /// this performs no validation — op-level validation happens at
    /// [`PjRtClient::compile`].  Exists for callers (and the robustness
    /// test suite) that already hold the text.
    pub fn from_text(text: &str) -> HloModuleProto {
        HloModuleProto {
            text: Arc::new(text.to_string()),
        }
    }
}

/// An XLA computation wrapping a parsed module.
#[derive(Clone, Debug)]
pub struct XlaComputation {
    module: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            module: proto.clone(),
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Backend {
    Interp,
    Stub,
}

/// PJRT client handle: the backend mode plus nothing else; creation never
/// fails for the in-crate backends.
#[derive(Clone, Debug)]
pub struct PjRtClient {
    backend: Backend,
}

impl PjRtClient {
    /// Backend from `DIVEBATCH_BACKEND` (default: the interpreter).
    pub fn cpu() -> Result<PjRtClient> {
        match std::env::var("DIVEBATCH_BACKEND").as_deref() {
            Err(_) | Ok("") | Ok("interp") => Ok(Self::interp()),
            Ok("stub") => Ok(Self::stub()),
            Ok(other) => Err(Error::Io(format!(
                "unknown DIVEBATCH_BACKEND {other:?} (expected \"interp\" or \"stub\")"
            ))),
        }
    }

    /// The pure-Rust HLO interpreter backend, regardless of environment.
    pub fn interp() -> PjRtClient {
        PjRtClient {
            backend: Backend::Interp,
        }
    }

    /// The compile-only stub backend, regardless of environment.
    pub fn stub() -> PjRtClient {
        PjRtClient {
            backend: Backend::Stub,
        }
    }

    pub fn platform_name(&self) -> String {
        match self.backend {
            Backend::Interp => INTERP_PLATFORM.to_string(),
            Backend::Stub => STUB_PLATFORM.to_string(),
        }
    }

    /// Compile a computation.  Under `interp` this parses the HLO text
    /// AND lowers it into the register program executed by
    /// [`PjRtLoadedExecutable::execute`] (clear error on anything outside
    /// the supported op subset — both phases happen here, so nothing
    /// fails mid-training); under `stub` it succeeds unconditionally so
    /// the executable cache is exercisable, and the product refuses to
    /// execute.
    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        let program = match self.backend {
            Backend::Stub => None,
            Backend::Interp => Some(Arc::new(interp::Compiled::compile(&comp.module.text)?)),
        };
        Ok(PjRtLoadedExecutable {
            hlo_bytes: comp.module.text.len(),
            program,
        })
    }
}

/// Device buffer handle returned by `execute`.  Under the interpreter it
/// holds the materialized result; the stub never constructs one.
#[derive(Clone, Debug)]
pub struct PjRtBuffer {
    value: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.value.clone())
    }
}

/// A compiled executable handle.
#[derive(Clone, Debug)]
pub struct PjRtLoadedExecutable {
    /// Size of the HLO text this was compiled from (debug visibility).
    pub hlo_bytes: usize,
    /// The compiled interpreter program (register program + retained
    /// parsed module); `None` under the compile-only stub.
    program: Option<Arc<interp::Compiled>>,
}

impl PjRtLoadedExecutable {
    /// Run the compiled register program.  Mirrors the real binding's
    /// return shape: `result[replica][output]`, with the entry's tuple
    /// result in `result[0][0]` (fetch with `to_literal_sync`, then
    /// `decompose_tuple`).
    pub fn execute<L: Borrow<Literal>>(&self, args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        let Some(program) = &self.program else {
            return Err(Error::StubBackend("cannot execute compiled HLO".into()));
        };
        let lits: Vec<&Literal> = args.iter().map(Borrow::borrow).collect();
        let value = program.execute(&lits)?;
        Ok(vec![vec![PjRtBuffer { value }]])
    }

    /// [`PjRtLoadedExecutable::execute`] at an explicit [`InterpTier`]
    /// instead of the `DIVEBATCH_INTERP_TIER` process default.  Both
    /// tiers return identical bits; the differential suite and the
    /// `perf_interp_simd` bench use this to compare them without mutating
    /// process-global env state.
    pub fn execute_with_tier<L: Borrow<Literal>>(
        &self,
        args: &[L],
        tier: InterpTier,
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        let Some(program) = &self.program else {
            return Err(Error::StubBackend("cannot execute compiled HLO".into()));
        };
        let lits: Vec<&Literal> = args.iter().map(Borrow::borrow).collect();
        let value = program.execute_with_tier(&lits, tier)?;
        Ok(vec![vec![PjRtBuffer { value }]])
    }

    /// Run through the retained pre-PR tree-walk evaluator instead of the
    /// compiled register program.  Exists for the differential test suite
    /// and the `perf_interp` bench's speedup baseline — production code
    /// paths must use [`PjRtLoadedExecutable::execute`].
    pub fn execute_reference<L: Borrow<Literal>>(
        &self,
        args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        let Some(program) = &self.program else {
            return Err(Error::StubBackend("cannot execute compiled HLO".into()));
        };
        let lits: Vec<&Literal> = args.iter().map(Borrow::borrow).collect();
        let value = program.execute_reference(&lits)?;
        Ok(vec![vec![PjRtBuffer { value }]])
    }

    /// Allocs-proxy counters of the compiled program's buffer arena:
    /// `(arenas created, buffers grown)`.  Steady-state execution keeps
    /// both flat — the `perf_interp` bench records them in BENCH_4.json.
    /// `None` under the compile-only stub.
    pub fn interp_arena_stats(&self) -> Option<(u64, u64)> {
        self.program.as_ref().map(|p| p.arena_stats())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_is_send_sync() {
        fn check<T: Send + Sync>() {}
        check::<PjRtClient>();
        check::<PjRtLoadedExecutable>();
        check::<PjRtBuffer>();
        check::<Literal>();
        check::<HloModuleProto>();
        check::<XlaComputation>();
        check::<Error>();
    }

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.get_first_element::<f32>().unwrap(), 1.0);
        assert!(l.to_vec::<i32>().is_err());
        assert!(l.reshape(&[2, 2]).is_ok());
        assert!(l.reshape(&[3, 2]).is_err());
        // Element-count mismatches and negative dims are rejected even
        // when the product happens to match.
        assert!(l.reshape(&[5]).is_err());
        assert!(l.reshape(&[-1, -4]).is_err());
        assert!(l.reshape(&[-2, -2]).is_err());
        let i = Literal::vec1(&[7i32]);
        assert_eq!(i.get_first_element::<i32>().unwrap(), 7);
    }

    fn write_hlo(name: &str, text: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("xla-vendor-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        std::fs::write(&path, text).unwrap();
        path
    }

    const DOUBLER: &str = r#"
HloModule doubler

ENTRY main.4 {
  Arg_0.1 = f32[3]{0} parameter(0)
  add.2 = f32[3]{0} add(Arg_0.1, Arg_0.1)
  ROOT tuple.3 = (f32[3]{0}) tuple(add.2)
}
"#;

    #[test]
    fn stub_compiles_but_refuses_to_execute() {
        let path = write_hlo("stub.hlo.txt", DOUBLER);
        let proto = HloModuleProto::from_text_file(path.to_str().unwrap()).unwrap();
        let comp = XlaComputation::from_proto(&proto);
        let client = PjRtClient::stub();
        assert_eq!(client.platform_name(), STUB_PLATFORM);
        let exe = client.compile(&comp).unwrap();
        assert!(exe.hlo_bytes > 0);
        let err = exe.execute::<Literal>(&[]).unwrap_err();
        assert!(err.to_string().contains("stub backend"), "{err}");

        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo").is_err());
    }

    #[test]
    fn interp_compiles_and_executes() {
        let path = write_hlo("interp.hlo.txt", DOUBLER);
        let proto = HloModuleProto::from_text_file(path.to_str().unwrap()).unwrap();
        let comp = XlaComputation::from_proto(&proto);
        let client = PjRtClient::interp();
        assert_eq!(client.platform_name(), INTERP_PLATFORM);
        let exe = client.compile(&comp).unwrap();
        let args = [Literal::vec1(&[1.0f32, -2.0, 0.5])];
        let result = exe.execute(&args).unwrap();
        let mut tuple = result[0][0].to_literal_sync().unwrap();
        let parts = tuple.decompose_tuple().unwrap();
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0].to_vec::<f32>().unwrap(), vec![2.0, -4.0, 1.0]);
        // Wrong arity / shape errors are descriptive.
        let e = exe.execute::<Literal>(&[]).unwrap_err().to_string();
        assert!(e.contains("parameters"), "{e}");
    }

    #[test]
    fn interp_rejects_malformed_hlo_at_compile() {
        let path = write_hlo("bad.hlo.txt", "HloModule nothing_here");
        let proto = HloModuleProto::from_text_file(path.to_str().unwrap()).unwrap();
        let comp = XlaComputation::from_proto(&proto);
        assert!(PjRtClient::interp().compile(&comp).is_err());
        // The stub accepts anything (compile-only).
        assert!(PjRtClient::stub().compile(&comp).is_ok());
    }

    #[test]
    fn cpu_defaults_to_interp() {
        // Do not mutate DIVEBATCH_BACKEND here (env is process-global and
        // tests run concurrently); the default path must be interp unless
        // the test environment explicitly forces the stub.
        if std::env::var("DIVEBATCH_BACKEND").is_err() {
            assert_eq!(PjRtClient::cpu().unwrap().platform_name(), INTERP_PLATFORM);
        }
    }
}
