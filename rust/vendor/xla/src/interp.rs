//! Pure-Rust HLO-text interpreter: the `interp` execution backend.
//!
//! Parses the HLO **text** interchange format emitted by the AOT pipeline
//! (python/compile/aot.py via `XlaComputation::as_hlo_text`) and evaluates
//! it on the host, so compiled entries execute with no native XLA at all.
//! This is a *reference* backend: correctness over speed, anchored by
//! golden outputs from the Python/jax side
//! (rust/tests/fixtures/golden_entry_outputs.json).
//!
//! Supported op subset — everything the repo's lowered entries use
//! (elementwise arithmetic + math, dot, reduce, broadcast, reshape,
//! transpose, slice, pad, concatenate, compare, select, convert,
//! constant, parameter, iota, tuple / get-tuple-element) over `f32`,
//! `s32` and `pred` element types.  Anything outside the subset (e.g.
//! convolution, while, custom-call from a non-interpret Pallas lowering)
//! fails at **compile** time with an error naming the opcode, so misuse
//! surfaces before any train loop starts.
//!
//! Numerics: elementwise math and dot/reduce accumulation are performed
//! in `f32`, mirroring the XLA CPU backend closely enough that the
//! committed goldens agree to ~1e-5 relative; evaluation order is fixed,
//! so results are bit-identical across runs and across engine workers
//! (the `jobs=1` vs `jobs=4` canonical-record equivalence relies on
//! this).

use std::collections::HashMap;
use std::fmt;

use crate::{Data, Error, Literal, Result};

// ------------------------------------------------------------------ shapes

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum DType {
    F32,
    S32,
    Pred,
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            DType::F32 => "f32",
            DType::S32 => "s32",
            DType::Pred => "pred",
        })
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub(crate) struct Shape {
    dtype: DType,
    dims: Vec<usize>,
}

impl Shape {
    fn elements(&self) -> usize {
        self.dims.iter().product()
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let dims: Vec<String> = self.dims.iter().map(|d| d.to_string()).collect();
        write!(f, "{}[{}]", self.dtype, dims.join(","))
    }
}

#[derive(Clone, Debug)]
enum ShapeSpec {
    Dense(Shape),
    Tuple(Vec<Shape>),
}

fn err(msg: String) -> Error {
    Error::Interp(msg)
}

fn parse_dense_shape(tok: &str) -> Result<Shape> {
    let tok = tok.trim();
    let (dt, rest) = tok
        .split_once('[')
        .ok_or_else(|| err(format!("malformed shape {tok:?}")))?;
    let dtype = match dt.trim() {
        "f32" => DType::F32,
        "s32" => DType::S32,
        "pred" => DType::Pred,
        other => {
            return Err(err(format!(
                "unsupported element type {other:?} (interp handles f32/s32/pred)"
            )))
        }
    };
    let (dims_str, _layout) = rest
        .split_once(']')
        .ok_or_else(|| err(format!("malformed shape {tok:?}")))?;
    let mut dims = Vec::new();
    if !dims_str.trim().is_empty() {
        for d in dims_str.split(',') {
            dims.push(
                d.trim()
                    .parse::<usize>()
                    .map_err(|_| err(format!("bad dimension {d:?} in shape {tok:?}")))?,
            );
        }
    }
    Ok(Shape { dtype, dims })
}

fn parse_shape_spec(s: &str) -> Result<ShapeSpec> {
    let s = s.trim();
    if let Some(inner) = s.strip_prefix('(') {
        let inner = inner
            .strip_suffix(')')
            .ok_or_else(|| err(format!("malformed tuple shape {s:?}")))?;
        let mut parts = Vec::new();
        for piece in split_top(inner, ',') {
            parts.push(parse_dense_shape(&piece)?);
        }
        Ok(ShapeSpec::Tuple(parts))
    } else {
        Ok(ShapeSpec::Dense(parse_dense_shape(s)?))
    }
}

/// Split on `sep` at nesting depth 0 w.r.t. `()`, `{}`, `[]`.
fn split_top(s: &str, sep: char) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0i32;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '(' | '{' | '[' => depth += 1,
            ')' | '}' | ']' => depth -= 1,
            _ => {}
        }
        if c == sep && depth == 0 {
            if !cur.trim().is_empty() {
                out.push(cur.trim().to_string());
            }
            cur.clear();
        } else {
            cur.push(c);
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur.trim().to_string());
    }
    out
}

// ------------------------------------------------------------------ values

#[derive(Clone, Debug)]
enum Buf {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Pred(Vec<bool>),
}

impl Buf {
    fn len(&self) -> usize {
        match self {
            Buf::F32(v) => v.len(),
            Buf::I32(v) => v.len(),
            Buf::Pred(v) => v.len(),
        }
    }

    fn dtype(&self) -> DType {
        match self {
            Buf::F32(_) => DType::F32,
            Buf::I32(_) => DType::S32,
            Buf::Pred(_) => DType::Pred,
        }
    }

    /// Lossless-for-our-dtypes scalar view (f32 and i32 embed exactly in
    /// f64; pred maps to 0/1) — used by structural ops only, which write
    /// the values straight back into the same dtype.
    fn get_f64(&self, i: usize) -> f64 {
        match self {
            Buf::F32(v) => v[i] as f64,
            Buf::I32(v) => v[i] as f64,
            Buf::Pred(v) => {
                if v[i] {
                    1.0
                } else {
                    0.0
                }
            }
        }
    }

    fn build(dtype: DType, vals: Vec<f64>) -> Buf {
        match dtype {
            DType::F32 => Buf::F32(vals.into_iter().map(|v| v as f32).collect()),
            DType::S32 => Buf::I32(vals.into_iter().map(|v| v as i32).collect()),
            DType::Pred => Buf::Pred(vals.into_iter().map(|v| v != 0.0).collect()),
        }
    }
}

#[derive(Clone, Debug)]
enum Value {
    Dense { dims: Vec<usize>, buf: Buf },
    Tuple(Vec<Value>),
}

impl Value {
    fn dense(&self) -> Result<(&[usize], &Buf)> {
        match self {
            Value::Dense { dims, buf } => Ok((dims, buf)),
            Value::Tuple(_) => Err(err("expected a dense (non-tuple) value".into())),
        }
    }

    fn f32s(&self) -> Result<&[f32]> {
        match self.dense()?.1 {
            Buf::F32(v) => Ok(v),
            other => Err(err(format!("expected f32 data, got {}", other.dtype()))),
        }
    }

    fn preds(&self) -> Result<&[bool]> {
        match self.dense()?.1 {
            Buf::Pred(v) => Ok(v),
            other => Err(err(format!("expected pred data, got {}", other.dtype()))),
        }
    }

    fn scalar_f32(&self) -> Result<f32> {
        let v = self.f32s()?;
        if v.len() != 1 {
            return Err(err(format!("expected a scalar, got {} elements", v.len())));
        }
        Ok(v[0])
    }
}

fn elements(dims: &[usize]) -> usize {
    dims.iter().product()
}

/// Row-major strides for `dims`.
fn strides(dims: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; dims.len()];
    for i in (0..dims.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * dims[i + 1];
    }
    s
}

/// Decompose a flat row-major index into coordinates.
fn coords_of(mut flat: usize, dims: &[usize], st: &[usize]) -> Vec<usize> {
    let mut c = vec![0usize; dims.len()];
    for i in 0..dims.len() {
        c[i] = flat / st[i];
        flat %= st[i];
    }
    c
}

// ------------------------------------------------------------ instructions

#[derive(Clone, Debug, Default)]
struct Attrs {
    dimensions: Vec<usize>,
    slice: Vec<(i64, i64, i64)>,
    padding: Vec<(i64, i64, i64)>,
    direction: Option<String>,
    to_apply: Option<String>,
    lhs_contracting: Vec<usize>,
    rhs_contracting: Vec<usize>,
    lhs_batch: Vec<usize>,
    rhs_batch: Vec<usize>,
    index: Option<usize>,
    iota_dimension: Option<usize>,
}

#[derive(Clone, Debug)]
struct Instr {
    name: String,
    shape: ShapeSpec,
    op: String,
    operands: Vec<usize>,
    attrs: Attrs,
    param: Option<usize>,
    literal: Option<Value>,
    is_root: bool,
}

#[derive(Clone, Debug)]
struct Computation {
    name: String,
    instrs: Vec<Instr>,
    root: usize,
    /// Instruction index by parameter number.
    params: Vec<usize>,
}

/// A parsed, executable HLO module.
#[derive(Debug)]
pub(crate) struct Module {
    computations: Vec<Computation>,
    by_name: HashMap<String, usize>,
    entry: usize,
}

/// Pre-resolution instruction: operand names instead of indices.
struct RawInstr {
    instr: Instr,
    operand_names: Vec<String>,
}

fn parse_usize_set(s: &str) -> Result<Vec<usize>> {
    let inner = s.trim().trim_start_matches('{').trim_end_matches('}');
    let mut out = Vec::new();
    for piece in inner.split(',') {
        let piece = piece.trim();
        if piece.is_empty() {
            continue;
        }
        out.push(
            piece
                .parse::<usize>()
                .map_err(|_| err(format!("bad integer list entry {piece:?}")))?,
        );
    }
    Ok(out)
}

fn parse_slice_spec(s: &str) -> Result<Vec<(i64, i64, i64)>> {
    // {[0:8], [1:3:2]}
    let inner = s.trim().trim_start_matches('{').trim_end_matches('}');
    let mut out = Vec::new();
    for piece in split_top(inner, ',') {
        let piece = piece.trim().trim_start_matches('[').trim_end_matches(']');
        let parts: Vec<&str> = piece.split(':').collect();
        if parts.len() != 2 && parts.len() != 3 {
            return Err(err(format!("bad slice spec {piece:?}")));
        }
        let p = |i: usize| -> Result<i64> {
            parts[i]
                .trim()
                .parse::<i64>()
                .map_err(|_| err(format!("bad slice bound {:?}", parts[i])))
        };
        let stride = if parts.len() == 3 { p(2)? } else { 1 };
        out.push((p(0)?, p(1)?, stride));
    }
    Ok(out)
}

fn parse_padding_spec(s: &str) -> Result<Vec<(i64, i64, i64)>> {
    // 8_0 | 0_1x2_3 | 1_1_2 (lo_hi[_interior] per dim, joined by x)
    let mut out = Vec::new();
    for piece in s.trim().split('x') {
        let parts: Vec<&str> = piece.split('_').collect();
        if parts.len() != 2 && parts.len() != 3 {
            return Err(err(format!("bad padding spec {piece:?}")));
        }
        let p = |i: usize| -> Result<i64> {
            parts[i]
                .trim()
                .parse::<i64>()
                .map_err(|_| err(format!("bad padding entry {:?}", parts[i])))
        };
        let interior = if parts.len() == 3 { p(2)? } else { 0 };
        out.push((p(0)?, p(1)?, interior));
    }
    Ok(out)
}

fn parse_constant_payload(payload: &str, shape: &Shape) -> Result<Value> {
    let toks: Vec<String> = payload
        .replace(['{', '}', ','], " ")
        .split_whitespace()
        .map(str::to_string)
        .collect();
    let want = shape.elements();
    if toks.len() != want {
        return Err(err(format!(
            "constant payload has {} values, shape {shape} wants {want}",
            toks.len()
        )));
    }
    let buf = match shape.dtype {
        DType::F32 => {
            let mut v = Vec::with_capacity(want);
            for t in &toks {
                v.push(
                    t.parse::<f32>()
                        .map_err(|_| err(format!("bad f32 constant {t:?}")))?,
                );
            }
            Buf::F32(v)
        }
        DType::S32 => {
            let mut v = Vec::with_capacity(want);
            for t in &toks {
                v.push(
                    t.parse::<i32>()
                        .map_err(|_| err(format!("bad s32 constant {t:?}")))?,
                );
            }
            Buf::I32(v)
        }
        DType::Pred => {
            let mut v = Vec::with_capacity(want);
            for t in &toks {
                v.push(match t.as_str() {
                    "true" | "1" => true,
                    "false" | "0" => false,
                    _ => return Err(err(format!("bad pred constant {t:?}"))),
                });
            }
            Buf::Pred(v)
        }
    };
    Ok(Value::Dense {
        dims: shape.dims.clone(),
        buf,
    })
}

/// Strip an operand token down to its instruction name: the last
/// whitespace-separated word (drops optional type prefixes in canonical
/// HLO), minus any leading `%`.
fn operand_name(tok: &str) -> String {
    tok.split_whitespace()
        .last()
        .unwrap_or("")
        .trim_start_matches('%')
        .to_string()
}

fn parse_instr(line: &str) -> Result<RawInstr> {
    let (lhs, rhs) = line
        .split_once(" = ")
        .ok_or_else(|| err(format!("malformed instruction {line:?}")))?;
    let lhs = lhs.trim();
    let is_root = lhs.starts_with("ROOT ");
    let name = lhs
        .trim_start_matches("ROOT ")
        .trim()
        .trim_start_matches('%')
        .to_string();

    // Shape: a leading parenthesized tuple type, or the first token.
    let rhs = rhs.trim();
    let (shape_str, rest) = if rhs.starts_with('(') {
        let mut depth = 0i32;
        let mut cut = None;
        for (i, c) in rhs.char_indices() {
            match c {
                '(' => depth += 1,
                ')' => {
                    depth -= 1;
                    if depth == 0 {
                        cut = Some(i + 1);
                        break;
                    }
                }
                _ => {}
            }
        }
        let cut = cut.ok_or_else(|| err(format!("unbalanced tuple shape in {line:?}")))?;
        (&rhs[..cut], rhs[cut..].trim_start())
    } else {
        let cut = rhs
            .find(' ')
            .ok_or_else(|| err(format!("malformed instruction {line:?}")))?;
        (&rhs[..cut], rhs[cut..].trim_start())
    };
    let shape = parse_shape_spec(shape_str)?;

    // Opcode, then its balanced parenthesized operand list.
    let open = rest
        .find('(')
        .ok_or_else(|| err(format!("missing operand list in {line:?}")))?;
    let op = rest[..open].trim().to_string();
    let mut depth = 0i32;
    let mut close = None;
    for (i, c) in rest.char_indices().skip(open) {
        match c {
            '(' => depth += 1,
            ')' => {
                depth -= 1;
                if depth == 0 {
                    close = Some(i);
                    break;
                }
            }
            _ => {}
        }
    }
    let close = close.ok_or_else(|| err(format!("unbalanced operand list in {line:?}")))?;
    let payload = &rest[open + 1..close];
    let attrs_str = rest[close + 1..].trim_start_matches(',').trim();

    let mut attrs = Attrs::default();
    for piece in split_top(attrs_str, ',') {
        let Some((key, val)) = piece.split_once('=') else {
            continue;
        };
        match key.trim() {
            "dimensions" => attrs.dimensions = parse_usize_set(val)?,
            "slice" => attrs.slice = parse_slice_spec(val)?,
            "padding" => attrs.padding = parse_padding_spec(val)?,
            "direction" => attrs.direction = Some(val.trim().to_string()),
            "to_apply" => {
                attrs.to_apply = Some(val.trim().trim_start_matches('%').to_string())
            }
            "lhs_contracting_dims" => attrs.lhs_contracting = parse_usize_set(val)?,
            "rhs_contracting_dims" => attrs.rhs_contracting = parse_usize_set(val)?,
            "lhs_batch_dims" => attrs.lhs_batch = parse_usize_set(val)?,
            "rhs_batch_dims" => attrs.rhs_batch = parse_usize_set(val)?,
            "index" => {
                attrs.index = Some(val.trim().parse::<usize>().map_err(|_| {
                    err(format!("bad get-tuple-element index {val:?}"))
                })?)
            }
            "iota_dimension" => {
                attrs.iota_dimension = Some(val.trim().parse::<usize>().map_err(|_| {
                    err(format!("bad iota_dimension {val:?}"))
                })?)
            }
            // metadata / frontend_attributes / backend_config / sharding /
            // operand_precision … are irrelevant to evaluation.
            _ => {}
        }
    }

    const SUPPORTED: &[&str] = &[
        "parameter",
        "constant",
        "add",
        "subtract",
        "multiply",
        "divide",
        "maximum",
        "minimum",
        "power",
        "remainder",
        "and",
        "or",
        "xor",
        "abs",
        "negate",
        "exponential",
        "exponential-minus-one",
        "log",
        "log-plus-one",
        "logistic",
        "tanh",
        "sqrt",
        "rsqrt",
        "sign",
        "floor",
        "ceil",
        "cosine",
        "sine",
        "not",
        "copy",
        "compare",
        "select",
        "convert",
        "broadcast",
        "reshape",
        "transpose",
        "slice",
        "pad",
        "concatenate",
        "dot",
        "reduce",
        "iota",
        "tuple",
        "get-tuple-element",
    ];
    if !SUPPORTED.contains(&op.as_str()) {
        return Err(err(format!(
            "unsupported HLO opcode {op:?} (instruction {name}) — the interp backend \
             covers the elementwise/dot/reduce/shape subset only; link the real \
             xla_extension binding for full HLO"
        )));
    }

    let mut param = None;
    let mut literal = None;
    let mut operand_names = Vec::new();
    match op.as_str() {
        "parameter" => {
            param = Some(payload.trim().parse::<usize>().map_err(|_| {
                err(format!("bad parameter number {payload:?}"))
            })?);
        }
        "constant" => {
            let ShapeSpec::Dense(s) = &shape else {
                return Err(err(format!("tuple-shaped constant in {line:?}")));
            };
            literal = Some(parse_constant_payload(payload, s)?);
        }
        _ => {
            for tok in split_top(payload, ',') {
                operand_names.push(operand_name(&tok));
            }
        }
    }

    Ok(RawInstr {
        instr: Instr {
            name,
            shape,
            op,
            operands: Vec::new(),
            attrs,
            param,
            literal,
            is_root,
        },
        operand_names,
    })
}

impl Module {
    /// Parse an HLO text module.  Unsupported opcodes are rejected here —
    /// at "compile" time — rather than mid-execution.
    pub(crate) fn parse(text: &str) -> Result<Module> {
        let mut computations: Vec<Computation> = Vec::new();
        let mut by_name: HashMap<String, usize> = HashMap::new();
        let mut entry: Option<usize> = None;
        let mut cur: Option<(String, bool, Vec<RawInstr>)> = None;

        for raw_line in text.lines() {
            let line = raw_line.trim();
            if line.is_empty() || line.starts_with("HloModule") || line.starts_with("//") {
                continue;
            }
            if line == "}" {
                let (name, is_entry, raws) =
                    cur.take().ok_or_else(|| err("stray '}' in HLO text".into()))?;
                let comp = build_computation(name, raws)?;
                let idx = computations.len();
                if by_name.insert(comp.name.clone(), idx).is_some() {
                    return Err(err(format!("duplicate computation {:?}", comp.name)));
                }
                if is_entry {
                    entry = Some(idx);
                }
                computations.push(comp);
                continue;
            }
            if line.ends_with('{') && !line.contains(" = ") {
                if cur.is_some() {
                    return Err(err("nested computation block in HLO text".into()));
                }
                let is_entry = line.starts_with("ENTRY ");
                let rest = line.strip_prefix("ENTRY ").unwrap_or(line);
                let tok = rest
                    .split_whitespace()
                    .next()
                    .ok_or_else(|| err("missing computation name".into()))?;
                let name = tok
                    .trim_start_matches('%')
                    .split('(')
                    .next()
                    .unwrap_or("")
                    .to_string();
                cur = Some((name, is_entry, Vec::new()));
                continue;
            }
            let Some((_, _, raws)) = cur.as_mut() else {
                return Err(err(format!("instruction outside computation: {line:?}")));
            };
            raws.push(parse_instr(line)?);
        }
        if cur.is_some() {
            return Err(err("unterminated computation block".into()));
        }
        let entry = match entry {
            Some(e) => e,
            None if computations.len() == 1 => 0,
            None => return Err(err("no ENTRY computation in HLO text".into())),
        };
        Ok(Module {
            computations,
            by_name,
            entry,
        })
    }

    fn computation(&self, name: &str) -> Result<&Computation> {
        self.by_name
            .get(name)
            .map(|&i| &self.computations[i])
            .ok_or_else(|| err(format!("unknown computation {name:?}")))
    }

    /// Execute the entry computation over argument literals.
    pub(crate) fn evaluate(&self, args: &[&Literal]) -> Result<Literal> {
        let comp = &self.computations[self.entry];
        if args.len() != comp.params.len() {
            return Err(err(format!(
                "entry {:?} takes {} parameters, got {} arguments",
                comp.name,
                comp.params.len(),
                args.len()
            )));
        }
        let mut vals = Vec::with_capacity(args.len());
        for (i, lit) in args.iter().enumerate() {
            let v = value_from_literal(lit)?;
            let pins = &comp.instrs[comp.params[i]];
            if let ShapeSpec::Dense(want) = &pins.shape {
                let (dims, buf) = v.dense()?;
                if dims != want.dims.as_slice() || buf.dtype() != want.dtype {
                    return Err(err(format!(
                        "argument {i} ({}): expected {want}, got {}[{}]",
                        pins.name,
                        buf.dtype(),
                        dims.iter()
                            .map(|d| d.to_string())
                            .collect::<Vec<_>>()
                            .join(",")
                    )));
                }
            }
            vals.push(v);
        }
        let out = self.eval_computation(comp, &vals)?;
        literal_from_value(out)
    }

    fn eval_computation(&self, comp: &Computation, args: &[Value]) -> Result<Value> {
        let mut env: Vec<Option<Value>> = vec![None; comp.instrs.len()];
        for idx in 0..comp.instrs.len() {
            let v = self.eval_instr(comp, idx, &env, args)?;
            env[idx] = Some(v);
        }
        Ok(env[comp.root].take().expect("root evaluated"))
    }

    fn eval_instr(
        &self,
        comp: &Computation,
        idx: usize,
        env: &[Option<Value>],
        args: &[Value],
    ) -> Result<Value> {
        let ins = &comp.instrs[idx];
        let opv = |i: usize| -> Result<&Value> {
            let oi = *ins.operands.get(i).ok_or_else(|| {
                err(format!("{}: missing operand {i}", ins.name))
            })?;
            env[oi]
                .as_ref()
                .ok_or_else(|| err(format!("{}: operand used before definition", ins.name)))
        };
        let out = match ins.op.as_str() {
            "parameter" => {
                let p = ins.param.expect("parameter number");
                args.get(p)
                    .ok_or_else(|| {
                        err(format!(
                            "{}: parameter({p}) exceeds the {} arguments supplied",
                            ins.name,
                            args.len()
                        ))
                    })?
                    .clone()
            }
            "constant" => ins.literal.clone().expect("parsed constant"),
            "add" | "subtract" | "multiply" | "divide" | "maximum" | "minimum" | "power"
            | "remainder" | "and" | "or" | "xor" => {
                binary_elementwise(&ins.op, opv(0)?, opv(1)?)?
            }
            "abs" | "negate" | "exponential" | "exponential-minus-one" | "log"
            | "log-plus-one" | "logistic" | "tanh" | "sqrt" | "rsqrt" | "sign" | "floor"
            | "ceil" | "cosine" | "sine" | "not" | "copy" => unary_elementwise(&ins.op, opv(0)?)?,
            "compare" => compare(
                ins.attrs
                    .direction
                    .as_deref()
                    .ok_or_else(|| err(format!("{}: compare without direction", ins.name)))?,
                opv(0)?,
                opv(1)?,
            )?,
            "select" => select(opv(0)?, opv(1)?, opv(2)?)?,
            "convert" => convert(opv(0)?, declared_dense(ins)?)?,
            "broadcast" => broadcast(opv(0)?, &ins.attrs.dimensions, declared_dense(ins)?)?,
            "reshape" => reshape(opv(0)?, declared_dense(ins)?)?,
            "transpose" => transpose(opv(0)?, &ins.attrs.dimensions)?,
            "slice" => slice(opv(0)?, &ins.attrs.slice)?,
            "pad" => pad(opv(0)?, opv(1)?, &ins.attrs.padding)?,
            "concatenate" => {
                let mut parts = Vec::with_capacity(ins.operands.len());
                for i in 0..ins.operands.len() {
                    parts.push(opv(i)?);
                }
                concatenate(&parts, ins.attrs.dimensions.first().copied().unwrap_or(0))?
            }
            "dot" => dot(opv(0)?, opv(1)?, &ins.attrs)?,
            "reduce" => self.reduce(opv(0)?, opv(1)?, &ins.attrs)?,
            "iota" => iota(declared_dense(ins)?, ins.attrs.iota_dimension.unwrap_or(0))?,
            "tuple" => {
                let mut parts = Vec::with_capacity(ins.operands.len());
                for i in 0..ins.operands.len() {
                    parts.push(opv(i)?.clone());
                }
                Value::Tuple(parts)
            }
            "get-tuple-element" => {
                let i = ins
                    .attrs
                    .index
                    .ok_or_else(|| err(format!("{}: get-tuple-element without index", ins.name)))?;
                match opv(0)? {
                    Value::Tuple(parts) => parts
                        .get(i)
                        .cloned()
                        .ok_or_else(|| err(format!("{}: tuple index {i} out of range", ins.name)))?,
                    Value::Dense { .. } => {
                        return Err(err(format!("{}: get-tuple-element of non-tuple", ins.name)))
                    }
                }
            }
            // Unreachable for modules from Module::parse (its SUPPORTED
            // allow-list screens opcodes); reachable only if that list
            // and these arms drift apart — report it as the bug it is.
            other => {
                return Err(err(format!(
                    "opcode {other:?} (instruction {}) passed the parse-time \
                     allow-list but has no evaluator — interp.rs SUPPORTED and \
                     eval_instr are out of sync",
                    ins.name
                )))
            }
        };
        // Self-check against the declared result shape: a mismatch means
        // an interpreter bug, better caught here than as silent numerics.
        if let (ShapeSpec::Dense(want), Value::Dense { dims, buf }) = (&ins.shape, &out) {
            if dims != &want.dims || buf.dtype() != want.dtype {
                return Err(err(format!(
                    "{}: interpreter produced {}[{}], HLO declares {want}",
                    ins.name,
                    buf.dtype(),
                    dims.iter()
                        .map(|d| d.to_string())
                        .collect::<Vec<_>>()
                        .join(",")
                )));
            }
        }
        Ok(out)
    }

    fn reduce(&self, data: &Value, init: &Value, attrs: &Attrs) -> Result<Value> {
        let (dims, buf) = data.dense()?;
        let red = &attrs.dimensions;
        let keep: Vec<usize> = (0..dims.len()).filter(|d| !red.contains(d)).collect();
        let out_dims: Vec<usize> = keep.iter().map(|&d| dims[d]).collect();
        let out_elems = elements(&out_dims);
        let comp_name = attrs
            .to_apply
            .as_deref()
            .ok_or_else(|| err("reduce without to_apply".into()))?;
        let comp = self.computation(comp_name)?;
        if comp.params.len() != 2 {
            return Err(err(format!(
                "reduce region {comp_name:?} takes {} parameters, expected 2",
                comp.params.len()
            )));
        }
        let fast = fast_binop(comp);
        let st = strides(dims);
        let out_st = strides(&out_dims);

        match buf {
            Buf::F32(v) => {
                let init = init.scalar_f32()?;
                let mut acc = vec![init; out_elems];
                for (flat, &x) in v.iter().enumerate() {
                    let c = coords_of(flat, dims, &st);
                    let mut of = 0usize;
                    for (k, &d) in keep.iter().enumerate() {
                        of += c[d] * out_st[k];
                    }
                    acc[of] = match fast {
                        Some("add") => acc[of] + x,
                        Some("multiply") => acc[of] * x,
                        Some("maximum") => acc[of].max(x),
                        Some("minimum") => acc[of].min(x),
                        _ => {
                            let a = Value::Dense {
                                dims: vec![],
                                buf: Buf::F32(vec![acc[of]]),
                            };
                            let b = Value::Dense {
                                dims: vec![],
                                buf: Buf::F32(vec![x]),
                            };
                            self.eval_computation(comp, &[a, b])?.scalar_f32()?
                        }
                    };
                }
                Ok(Value::Dense {
                    dims: out_dims,
                    buf: Buf::F32(acc),
                })
            }
            other => Err(err(format!(
                "reduce over {} is not supported by the interp backend",
                other.dtype()
            ))),
        }
    }
}

fn build_computation(name: String, raws: Vec<RawInstr>) -> Result<Computation> {
    let mut index: HashMap<String, usize> = HashMap::new();
    for (i, r) in raws.iter().enumerate() {
        if index.insert(r.instr.name.clone(), i).is_some() {
            return Err(err(format!(
                "duplicate instruction name {:?} in computation {name:?}",
                r.instr.name
            )));
        }
    }
    let mut instrs = Vec::with_capacity(raws.len());
    let mut params: Vec<(usize, usize)> = Vec::new();
    let mut root = None;
    for (i, raw) in raws.into_iter().enumerate() {
        let mut ins = raw.instr;
        for on in &raw.operand_names {
            let oi = *index.get(on).ok_or_else(|| {
                err(format!(
                    "unknown operand {on:?} of {:?} in computation {name:?}",
                    ins.name
                ))
            })?;
            ins.operands.push(oi);
        }
        if let Some(p) = ins.param {
            params.push((p, i));
        }
        if ins.is_root {
            root = Some(i);
        }
        instrs.push(ins);
    }
    let root = root.unwrap_or(instrs.len().saturating_sub(1));
    if instrs.is_empty() {
        return Err(err(format!("empty computation {name:?}")));
    }
    params.sort();
    for (want, &(got, _)) in params.iter().enumerate() {
        if want != got {
            return Err(err(format!(
                "computation {name:?} has non-contiguous parameter numbers"
            )));
        }
    }
    let params = params.into_iter().map(|(_, i)| i).collect();
    Ok(Computation {
        name,
        instrs,
        root,
        params,
    })
}

/// If `comp` is a single binary op over its two parameters, return the op
/// name (fast-path for reduce regions, which jax emits as one-op adds).
fn fast_binop(comp: &Computation) -> Option<&str> {
    if comp.instrs.len() != 3 || comp.params.len() != 2 {
        return None;
    }
    let root = &comp.instrs[comp.root];
    if root.operands.len() == 2
        && comp.instrs[root.operands[0]].op == "parameter"
        && comp.instrs[root.operands[1]].op == "parameter"
    {
        Some(root.op.as_str())
    } else {
        None
    }
}

fn declared_dense(ins: &Instr) -> Result<&Shape> {
    match &ins.shape {
        ShapeSpec::Dense(s) => Ok(s),
        ShapeSpec::Tuple(_) => Err(err(format!("{}: unexpected tuple shape", ins.name))),
    }
}

// -------------------------------------------------------------- op kernels

fn same_dims<'v>(a: &'v Value, b: &'v Value) -> Result<(&'v [usize], &'v Buf, &'v Buf)> {
    let (da, ba) = a.dense()?;
    let (db, bb) = b.dense()?;
    if da != db {
        return Err(err(format!(
            "shape mismatch in elementwise op: [{}] vs [{}]",
            da.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(","),
            db.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(",")
        )));
    }
    Ok((da, ba, bb))
}

fn binary_elementwise(op: &str, a: &Value, b: &Value) -> Result<Value> {
    let (dims, ba, bb) = same_dims(a, b)?;
    let buf = match (ba, bb) {
        (Buf::F32(x), Buf::F32(y)) => {
            let f: fn(f32, f32) -> f32 = match op {
                "add" => |a, b| a + b,
                "subtract" => |a, b| a - b,
                "multiply" => |a, b| a * b,
                "divide" => |a, b| a / b,
                "maximum" => f32::max,
                "minimum" => f32::min,
                "power" => f32::powf,
                "remainder" => |a, b| a % b,
                _ => return Err(err(format!("op {op:?} not defined for f32"))),
            };
            Buf::F32(x.iter().zip(y).map(|(&a, &b)| f(a, b)).collect())
        }
        (Buf::I32(x), Buf::I32(y)) => {
            let f: fn(i32, i32) -> i32 = match op {
                "add" => i32::wrapping_add,
                "subtract" => i32::wrapping_sub,
                "multiply" => i32::wrapping_mul,
                "maximum" => i32::max,
                "minimum" => i32::min,
                "and" => |a, b| a & b,
                "or" => |a, b| a | b,
                "xor" => |a, b| a ^ b,
                _ => return Err(err(format!("op {op:?} not defined for s32"))),
            };
            Buf::I32(x.iter().zip(y).map(|(&a, &b)| f(a, b)).collect())
        }
        (Buf::Pred(x), Buf::Pred(y)) => {
            let f: fn(bool, bool) -> bool = match op {
                "and" => |a, b| a && b,
                "or" => |a, b| a || b,
                "xor" => |a, b| a ^ b,
                _ => return Err(err(format!("op {op:?} not defined for pred"))),
            };
            Buf::Pred(x.iter().zip(y).map(|(&a, &b)| f(a, b)).collect())
        }
        _ => {
            return Err(err(format!(
                "mixed element types in {op:?}: {} vs {}",
                ba.dtype(),
                bb.dtype()
            )))
        }
    };
    Ok(Value::Dense {
        dims: dims.to_vec(),
        buf,
    })
}

fn unary_elementwise(op: &str, a: &Value) -> Result<Value> {
    let (dims, buf) = a.dense()?;
    let out = match buf {
        Buf::F32(v) => {
            let f: fn(f32) -> f32 = match op {
                "abs" => f32::abs,
                "negate" => |x| -x,
                "exponential" => f32::exp,
                "exponential-minus-one" => f32::exp_m1,
                "log" => f32::ln,
                "log-plus-one" => f32::ln_1p,
                "logistic" => |x| 1.0 / (1.0 + (-x).exp()),
                "tanh" => f32::tanh,
                "sqrt" => f32::sqrt,
                "rsqrt" => |x| 1.0 / x.sqrt(),
                "sign" => |x| {
                    if x == 0.0 {
                        0.0
                    } else {
                        x.signum()
                    }
                },
                "floor" => f32::floor,
                "ceil" => f32::ceil,
                "cosine" => f32::cos,
                "sine" => f32::sin,
                "copy" => |x| x,
                _ => return Err(err(format!("op {op:?} not defined for f32"))),
            };
            Buf::F32(v.iter().map(|&x| f(x)).collect())
        }
        Buf::I32(v) => {
            let f: fn(i32) -> i32 = match op {
                "abs" => i32::wrapping_abs,
                "negate" => i32::wrapping_neg,
                "sign" => i32::signum,
                "copy" => |x| x,
                _ => return Err(err(format!("op {op:?} not defined for s32"))),
            };
            Buf::I32(v.iter().map(|&x| f(x)).collect())
        }
        Buf::Pred(v) => match op {
            "not" => Buf::Pred(v.iter().map(|&x| !x).collect()),
            "copy" => Buf::Pred(v.clone()),
            _ => return Err(err(format!("op {op:?} not defined for pred"))),
        },
    };
    Ok(Value::Dense {
        dims: dims.to_vec(),
        buf: out,
    })
}

fn compare(direction: &str, a: &Value, b: &Value) -> Result<Value> {
    let (dims, ba, bb) = same_dims(a, b)?;
    let n = ba.len();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let ord = match (ba, bb) {
            (Buf::F32(x), Buf::F32(y)) => x[i].partial_cmp(&y[i]),
            (Buf::I32(x), Buf::I32(y)) => Some(x[i].cmp(&y[i])),
            (Buf::Pred(x), Buf::Pred(y)) => Some(x[i].cmp(&y[i])),
            _ => {
                return Err(err(format!(
                    "mixed element types in compare: {} vs {}",
                    ba.dtype(),
                    bb.dtype()
                )))
            }
        };
        // `ord` is None only for NaN: all comparisons false except NE.
        let r = match direction {
            "EQ" => ord == Some(std::cmp::Ordering::Equal),
            "NE" => ord != Some(std::cmp::Ordering::Equal),
            "LT" => ord == Some(std::cmp::Ordering::Less),
            "GT" => ord == Some(std::cmp::Ordering::Greater),
            "LE" => matches!(
                ord,
                Some(std::cmp::Ordering::Less | std::cmp::Ordering::Equal)
            ),
            "GE" => matches!(
                ord,
                Some(std::cmp::Ordering::Greater | std::cmp::Ordering::Equal)
            ),
            other => return Err(err(format!("unknown compare direction {other:?}"))),
        };
        out.push(r);
    }
    Ok(Value::Dense {
        dims: dims.to_vec(),
        buf: Buf::Pred(out),
    })
}

fn select(pred: &Value, on_true: &Value, on_false: &Value) -> Result<Value> {
    let p = pred.preds()?;
    let (dims, bt, bf) = same_dims(on_true, on_false)?;
    let n = bt.len();
    if p.len() != n && p.len() != 1 {
        return Err(err(format!(
            "select predicate has {} elements, operands have {n}",
            p.len()
        )));
    }
    let pick = |i: usize| -> bool {
        if p.len() == 1 {
            p[0]
        } else {
            p[i]
        }
    };
    let buf = match (bt, bf) {
        (Buf::F32(t), Buf::F32(f)) => {
            Buf::F32((0..n).map(|i| if pick(i) { t[i] } else { f[i] }).collect())
        }
        (Buf::I32(t), Buf::I32(f)) => {
            Buf::I32((0..n).map(|i| if pick(i) { t[i] } else { f[i] }).collect())
        }
        (Buf::Pred(t), Buf::Pred(f)) => {
            Buf::Pred((0..n).map(|i| if pick(i) { t[i] } else { f[i] }).collect())
        }
        _ => return Err(err("mixed element types in select".into())),
    };
    Ok(Value::Dense {
        dims: dims.to_vec(),
        buf,
    })
}

fn convert(a: &Value, want: &Shape) -> Result<Value> {
    let (dims, buf) = a.dense()?;
    let n = buf.len();
    let out = match (buf, want.dtype) {
        (Buf::F32(v), DType::F32) => Buf::F32(v.clone()),
        (Buf::I32(v), DType::S32) => Buf::I32(v.clone()),
        (Buf::Pred(v), DType::Pred) => Buf::Pred(v.clone()),
        (Buf::Pred(v), DType::F32) => {
            Buf::F32(v.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect())
        }
        (Buf::Pred(v), DType::S32) => Buf::I32(v.iter().map(|&b| b as i32).collect()),
        (Buf::I32(v), DType::F32) => Buf::F32(v.iter().map(|&x| x as f32).collect()),
        (Buf::F32(v), DType::S32) => {
            // XLA convert f32->s32 rounds toward zero.
            Buf::I32(v.iter().map(|&x| x as i32).collect())
        }
        (Buf::F32(v), DType::Pred) => Buf::Pred(v.iter().map(|&x| x != 0.0).collect()),
        (Buf::I32(v), DType::Pred) => Buf::Pred(v.iter().map(|&x| x != 0).collect()),
    };
    debug_assert_eq!(out.len(), n);
    Ok(Value::Dense {
        dims: dims.to_vec(),
        buf: out,
    })
}

fn broadcast(a: &Value, mapping: &[usize], want: &Shape) -> Result<Value> {
    let (in_dims, buf) = a.dense()?;
    if mapping.len() != in_dims.len() {
        return Err(err(format!(
            "broadcast dimensions {:?} do not cover operand rank {}",
            mapping,
            in_dims.len()
        )));
    }
    for (i, &od) in mapping.iter().enumerate() {
        // A mapped dim must match the output dim or be degenerate (1).
        if od >= want.dims.len() || (want.dims[od] != in_dims[i] && in_dims[i] != 1) {
            return Err(err(format!(
                "broadcast maps operand dim {i} (size {}) to output dim {od} of {want}",
                in_dims[i]
            )));
        }
    }
    let out_dims = want.dims.clone();
    let out_elems = elements(&out_dims);
    let out_st = strides(&out_dims);
    let in_st = strides(in_dims);
    let mut vals = Vec::with_capacity(out_elems);
    for flat in 0..out_elems {
        let c = coords_of(flat, &out_dims, &out_st);
        let mut inf = 0usize;
        for (i, &od) in mapping.iter().enumerate() {
            let ci = if in_dims[i] == 1 { 0 } else { c[od] };
            inf += ci * in_st[i];
        }
        vals.push(buf.get_f64(inf));
    }
    Ok(Value::Dense {
        dims: out_dims,
        buf: Buf::build(buf.dtype(), vals),
    })
}

fn reshape(a: &Value, want: &Shape) -> Result<Value> {
    let (in_dims, buf) = a.dense()?;
    if elements(in_dims) != want.elements() {
        return Err(err(format!(
            "reshape element count mismatch: {} -> {want}",
            elements(in_dims)
        )));
    }
    Ok(Value::Dense {
        dims: want.dims.clone(),
        buf: buf.clone(),
    })
}

fn transpose(a: &Value, perm: &[usize]) -> Result<Value> {
    let (in_dims, buf) = a.dense()?;
    if perm.len() != in_dims.len() || perm.iter().any(|&p| p >= in_dims.len()) {
        return Err(err(format!(
            "transpose permutation {:?} is not a permutation of rank {}",
            perm,
            in_dims.len()
        )));
    }
    let out_dims: Vec<usize> = perm.iter().map(|&p| in_dims[p]).collect();
    let out_st = strides(&out_dims);
    let in_st = strides(in_dims);
    let n = elements(&out_dims);
    let mut vals = Vec::with_capacity(n);
    for flat in 0..n {
        let c = coords_of(flat, &out_dims, &out_st);
        let mut inf = 0usize;
        for (i, &p) in perm.iter().enumerate() {
            inf += c[i] * in_st[p];
        }
        vals.push(buf.get_f64(inf));
    }
    Ok(Value::Dense {
        dims: out_dims,
        buf: Buf::build(buf.dtype(), vals),
    })
}

fn slice(a: &Value, spec: &[(i64, i64, i64)]) -> Result<Value> {
    let (in_dims, buf) = a.dense()?;
    if spec.len() != in_dims.len() {
        return Err(err(format!(
            "slice spec rank {} does not match operand rank {}",
            spec.len(),
            in_dims.len()
        )));
    }
    let mut out_dims = Vec::with_capacity(spec.len());
    for (d, &(start, limit, stride)) in spec.iter().enumerate() {
        if stride <= 0 || start < 0 || limit < start || limit as usize > in_dims[d] {
            return Err(err(format!(
                "invalid slice [{start}:{limit}:{stride}] for dimension of size {}",
                in_dims[d]
            )));
        }
        out_dims.push(((limit - start) as usize).div_ceil(stride as usize));
    }
    let out_st = strides(&out_dims);
    let in_st = strides(in_dims);
    let n = elements(&out_dims);
    let mut vals = Vec::with_capacity(n);
    for flat in 0..n {
        let c = coords_of(flat, &out_dims, &out_st);
        let mut inf = 0usize;
        for (d, &(start, _, stride)) in spec.iter().enumerate() {
            inf += (start as usize + c[d] * stride as usize) * in_st[d];
        }
        vals.push(buf.get_f64(inf));
    }
    Ok(Value::Dense {
        dims: out_dims,
        buf: Buf::build(buf.dtype(), vals),
    })
}

fn pad(a: &Value, fill: &Value, spec: &[(i64, i64, i64)]) -> Result<Value> {
    let (in_dims, buf) = a.dense()?;
    let (fdims, fbuf) = fill.dense()?;
    if !fdims.is_empty() || fbuf.len() != 1 {
        return Err(err("pad fill value must be a scalar".into()));
    }
    if spec.len() != in_dims.len() {
        return Err(err(format!(
            "padding spec rank {} does not match operand rank {}",
            spec.len(),
            in_dims.len()
        )));
    }
    let mut out_dims = Vec::with_capacity(spec.len());
    for (d, &(lo, hi, interior)) in spec.iter().enumerate() {
        if interior < 0 {
            return Err(err("negative interior padding".into()));
        }
        let n = in_dims[d] as i64;
        let stretched = if n == 0 { 0 } else { n + (n - 1) * interior };
        let total = lo + stretched + hi;
        if total < 0 {
            return Err(err(format!("padding {lo}_{hi} collapses dimension {d}")));
        }
        out_dims.push(total as usize);
    }
    let out_elems = elements(&out_dims);
    let fill_v = fbuf.get_f64(0);
    let mut vals = vec![fill_v; out_elems];
    let in_st = strides(in_dims);
    let out_st = strides(&out_dims);
    let in_elems = elements(in_dims);
    'next: for flat in 0..in_elems {
        let c = coords_of(flat, in_dims, &in_st);
        let mut of = 0usize;
        for (d, &(lo, _, interior)) in spec.iter().enumerate() {
            let pos = lo + c[d] as i64 * (1 + interior);
            if pos < 0 || pos as usize >= out_dims[d] {
                continue 'next; // cropped away by negative padding
            }
            of += pos as usize * out_st[d];
        }
        vals[of] = buf.get_f64(flat);
    }
    Ok(Value::Dense {
        dims: out_dims,
        buf: Buf::build(buf.dtype(), vals),
    })
}

fn concatenate(parts: &[&Value], dim: usize) -> Result<Value> {
    if parts.is_empty() {
        return Err(err("concatenate with no operands".into()));
    }
    let (d0, b0) = parts[0].dense()?;
    if dim >= d0.len() {
        return Err(err(format!(
            "concatenate dimension {dim} out of range for rank {}",
            d0.len()
        )));
    }
    let dtype = b0.dtype();
    let mut out_dims = d0.to_vec();
    out_dims[dim] = 0;
    for p in parts {
        let (d, b) = p.dense()?;
        if d.len() != d0.len() || b.dtype() != dtype {
            return Err(err("concatenate operand shape/type mismatch".into()));
        }
        out_dims[dim] += d[dim];
    }
    let out_st = strides(&out_dims);
    let n = elements(&out_dims);
    let mut vals = Vec::with_capacity(n);
    for flat in 0..n {
        let mut c = coords_of(flat, &out_dims, &out_st);
        let mut k = c[dim];
        let mut src = None;
        for p in parts {
            let (d, b) = p.dense()?;
            if k < d[dim] {
                c[dim] = k;
                let st = strides(d);
                let inf: usize = c.iter().zip(&st).map(|(&ci, &si)| ci * si).sum();
                src = Some(b.get_f64(inf));
                break;
            }
            k -= d[dim];
        }
        vals.push(src.expect("concatenate source found"));
    }
    Ok(Value::Dense {
        dims: out_dims,
        buf: Buf::build(dtype, vals),
    })
}

fn dot(a: &Value, b: &Value, attrs: &Attrs) -> Result<Value> {
    if !attrs.lhs_batch.is_empty() || !attrs.rhs_batch.is_empty() {
        return Err(err("dot with batch dimensions is not supported".into()));
    }
    if attrs.lhs_contracting.len() != 1 || attrs.rhs_contracting.len() != 1 {
        return Err(err(
            "dot requires exactly one contracting dimension per side".into(),
        ));
    }
    let (lc, rc) = (attrs.lhs_contracting[0], attrs.rhs_contracting[0]);
    let la = a.f32s()?;
    let rb = b.f32s()?;
    let (ld, _) = a.dense()?;
    let (rd, _) = b.dense()?;
    if lc >= ld.len() || rc >= rd.len() || ld[lc] != rd[rc] {
        return Err(err(format!(
            "dot contraction mismatch: lhs dim {lc} of {ld:?} vs rhs dim {rc} of {rd:?}"
        )));
    }
    let k = ld[lc];
    let lfree: Vec<usize> = (0..ld.len()).filter(|&d| d != lc).collect();
    let rfree: Vec<usize> = (0..rd.len()).filter(|&d| d != rc).collect();
    let out_dims: Vec<usize> = lfree
        .iter()
        .map(|&d| ld[d])
        .chain(rfree.iter().map(|&d| rd[d]))
        .collect();
    let l_st = strides(ld);
    let r_st = strides(rd);
    let out_st = strides(&out_dims);
    let n = elements(&out_dims);
    let mut out = Vec::with_capacity(n);
    for flat in 0..n {
        let c = coords_of(flat, &out_dims, &out_st);
        let mut lbase = 0usize;
        for (i, &d) in lfree.iter().enumerate() {
            lbase += c[i] * l_st[d];
        }
        let mut rbase = 0usize;
        for (i, &d) in rfree.iter().enumerate() {
            rbase += c[lfree.len() + i] * r_st[d];
        }
        let mut acc = 0.0f32;
        for kk in 0..k {
            acc += la[lbase + kk * l_st[lc]] * rb[rbase + kk * r_st[rc]];
        }
        out.push(acc);
    }
    Ok(Value::Dense {
        dims: out_dims,
        buf: Buf::F32(out),
    })
}

fn iota(want: &Shape, dim: usize) -> Result<Value> {
    if dim >= want.dims.len().max(1) {
        return Err(err(format!("iota dimension {dim} out of range for {want}")));
    }
    let st = strides(&want.dims);
    let n = want.elements();
    let mut vals = Vec::with_capacity(n);
    for flat in 0..n {
        let c = coords_of(flat, &want.dims, &st);
        vals.push(c.get(dim).copied().unwrap_or(0) as f64);
    }
    Ok(Value::Dense {
        dims: want.dims.clone(),
        buf: Buf::build(want.dtype, vals),
    })
}

// ----------------------------------------------------- literal conversion

fn value_from_literal(l: &Literal) -> Result<Value> {
    let (data, dims) = l
        .dense_parts()
        .ok_or_else(|| err("tuple arguments are not supported".into()))?;
    let mut ud = Vec::with_capacity(dims.len());
    for &d in dims {
        if d < 0 {
            return Err(err(format!("negative dimension {d} in argument")));
        }
        ud.push(d as usize);
    }
    let buf = match data {
        Data::F32(v) => Buf::F32(v.clone()),
        Data::I32(v) => Buf::I32(v.clone()),
    };
    if buf.len() != elements(&ud) {
        return Err(err(format!(
            "argument has {} elements but dims {ud:?}",
            buf.len()
        )));
    }
    Ok(Value::Dense { dims: ud, buf })
}

fn literal_from_value(v: Value) -> Result<Literal> {
    match v {
        Value::Dense { dims, buf } => {
            let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            let data = match buf {
                Buf::F32(v) => Data::F32(v),
                Buf::I32(v) => Data::I32(v),
                Buf::Pred(v) => Data::I32(v.into_iter().map(i32::from).collect()),
            };
            Ok(Literal::from_data(data, dims))
        }
        Value::Tuple(parts) => {
            let mut out = Vec::with_capacity(parts.len());
            for p in parts {
                out.push(literal_from_value(p)?);
            }
            Ok(Literal::tuple(out))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn eval(text: &str, args: &[&Literal]) -> Vec<Literal> {
        let module = Module::parse(text).unwrap();
        let mut root = module.evaluate(args).unwrap();
        match root.decompose_tuple() {
            Ok(parts) => parts,
            Err(_) => vec![root],
        }
    }

    #[test]
    fn matvec_bias_roundtrip() {
        // y = x @ w + b over f32[2,3] x f32[3], b broadcast from w tail.
        let text = r#"
HloModule t, entry_computation_layout={(f32[4]{0}, f32[2,3]{1,0})->(f32[2])}

ENTRY main.10 {
  Arg_0.1 = f32[4]{0} parameter(0)
  Arg_1.2 = f32[2,3]{1,0} parameter(1)
  slice.3 = f32[3]{0} slice(Arg_0.1), slice={[0:3]}
  dot.4 = f32[2]{0} dot(Arg_1.2, slice.3), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  slice.5 = f32[1]{0} slice(Arg_0.1), slice={[3:4]}
  reshape.6 = f32[] reshape(slice.5)
  broadcast.7 = f32[2]{0} broadcast(reshape.6), dimensions={}
  add.8 = f32[2]{0} add(dot.4, broadcast.7)
  ROOT tuple.9 = (f32[2]{0}) tuple(add.8)
}
"#;
        let params = Literal::vec1(&[1.0f32, 2.0, 3.0, 0.5]);
        let x = Literal::vec1(&[1.0f32, 0.0, -1.0, 2.0, 2.0, 2.0])
            .reshape(&[2, 3])
            .unwrap();
        let out = eval(text, &[&params, &x]);
        assert_eq!(out.len(), 1);
        // Row 0: 1*1 + 0*2 + -1*3 + 0.5 = -1.5; row 1: 2+4+6+0.5 = 12.5.
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![-1.5, 12.5]);
    }

    #[test]
    fn reduce_rows_and_columns() {
        let text = r#"
HloModule t

region_0.1 {
  Arg_0.2 = f32[] parameter(0)
  Arg_1.3 = f32[] parameter(1)
  ROOT add.4 = f32[] add(Arg_0.2, Arg_1.3)
}

ENTRY main.10 {
  Arg_0.1 = f32[2,3]{1,0} parameter(0)
  constant.2 = f32[] constant(0)
  reduce.3 = f32[2]{0} reduce(Arg_0.1, constant.2), dimensions={1}, to_apply=region_0.1
  reduce.4 = f32[3]{0} reduce(Arg_0.1, constant.2), dimensions={0}, to_apply=region_0.1
  reduce.5 = f32[] reduce(Arg_0.1, constant.2), dimensions={0,1}, to_apply=region_0.1
  ROOT tuple.6 = (f32[2]{0}, f32[3]{0}, f32[]) tuple(reduce.3, reduce.4, reduce.5)
}
"#;
        let x = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0])
            .reshape(&[2, 3])
            .unwrap();
        let out = eval(text, &[&x]);
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![6.0, 15.0]);
        assert_eq!(out[1].to_vec::<f32>().unwrap(), vec![5.0, 7.0, 9.0]);
        assert_eq!(out[2].get_first_element::<f32>().unwrap(), 21.0);
    }

    #[test]
    fn compare_select_convert_pad() {
        let text = r#"
HloModule t

ENTRY main.12 {
  Arg_0.1 = f32[4]{0} parameter(0)
  constant.2 = f32[] constant(0)
  broadcast.3 = f32[4]{0} broadcast(constant.2), dimensions={}
  compare.4 = pred[4]{0} compare(Arg_0.1, broadcast.3), direction=GT
  convert.5 = f32[4]{0} convert(compare.4)
  negate.6 = f32[4]{0} negate(Arg_0.1)
  select.7 = f32[4]{0} select(compare.4, Arg_0.1, negate.6)
  pad.8 = f32[6]{0} pad(select.7, constant.2), padding=1_1
  ROOT tuple.9 = (f32[4]{0}, f32[6]{0}) tuple(convert.5, pad.8)
}
"#;
        let x = Literal::vec1(&[1.5f32, -2.0, 0.0, 3.0]);
        let out = eval(text, &[&x]);
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![1.0, 0.0, 0.0, 1.0]);
        // select implements |x|; pad adds one zero each side.
        assert_eq!(
            out[1].to_vec::<f32>().unwrap(),
            vec![0.0, 1.5, 2.0, 0.0, 3.0, 0.0]
        );
    }

    #[test]
    fn transpose_concatenate_iota() {
        let text = r#"
HloModule t

ENTRY main.7 {
  Arg_0.1 = f32[2,3]{1,0} parameter(0)
  transpose.2 = f32[3,2]{1,0} transpose(Arg_0.1), dimensions={1,0}
  reshape.3 = f32[6]{0} reshape(transpose.2)
  iota.4 = f32[2]{0} iota(), iota_dimension=0
  concatenate.5 = f32[8]{0} concatenate(reshape.3, iota.4), dimensions={0}
  ROOT tuple.6 = (f32[8]{0}) tuple(concatenate.5)
}
"#;
        let x = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0])
            .reshape(&[2, 3])
            .unwrap();
        let out = eval(text, &[&x]);
        assert_eq!(
            out[0].to_vec::<f32>().unwrap(),
            vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0, 0.0, 1.0]
        );
    }

    #[test]
    fn math_unaries_match_std() {
        let text = r#"
HloModule t

ENTRY main.8 {
  Arg_0.1 = f32[3]{0} parameter(0)
  exponential.2 = f32[3]{0} exponential(Arg_0.1)
  log-plus-one.3 = f32[3]{0} log-plus-one(Arg_0.1)
  logistic.4 = f32[3]{0} logistic(Arg_0.1)
  abs.5 = f32[3]{0} abs(Arg_0.1)
  ROOT tuple.6 = (f32[3]{0}, f32[3]{0}, f32[3]{0}, f32[3]{0}) tuple(exponential.2, log-plus-one.3, logistic.4, abs.5)
}
"#;
        let xs = [0.5f32, -1.25, 2.0];
        let out = eval(text, &[&Literal::vec1(&xs)]);
        let exp = out[0].to_vec::<f32>().unwrap();
        let l1p = out[1].to_vec::<f32>().unwrap();
        let sig = out[2].to_vec::<f32>().unwrap();
        let abs = out[3].to_vec::<f32>().unwrap();
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(exp[i], x.exp());
            assert_eq!(l1p[i], x.ln_1p());
            assert!((sig[i] - 1.0 / (1.0 + (-x).exp())).abs() < 1e-7);
            assert_eq!(abs[i], x.abs());
        }
    }

    #[test]
    fn constants_including_inf_and_arrays() {
        let text = r#"
HloModule t

ENTRY main.5 {
  constant.1 = f32[] constant(inf)
  constant.2 = f32[3]{0} constant({1, -2.5, 3e2})
  constant.3 = s32[2]{0} constant({7, -9})
  ROOT tuple.4 = (f32[], f32[3]{0}, s32[2]{0}) tuple(constant.1, constant.2, constant.3)
}
"#;
        let out = eval(text, &[]);
        assert_eq!(out[0].get_first_element::<f32>().unwrap(), f32::INFINITY);
        assert_eq!(out[1].to_vec::<f32>().unwrap(), vec![1.0, -2.5, 300.0]);
        assert_eq!(out[2].to_vec::<i32>().unwrap(), vec![7, -9]);
    }

    #[test]
    fn argument_validation_names_parameter_and_shapes() {
        let text = r#"
HloModule t

ENTRY main.3 {
  Arg_0.1 = f32[4]{0} parameter(0)
  ROOT tuple.2 = (f32[4]{0}) tuple(Arg_0.1)
}
"#;
        let module = Module::parse(text).unwrap();
        let bad = Literal::vec1(&[1.0f32, 2.0]);
        let e = module.evaluate(&[&bad]).unwrap_err().to_string();
        assert!(e.contains("Arg_0.1") && e.contains("f32[4]"), "{e}");
        let e = module.evaluate(&[]).unwrap_err().to_string();
        assert!(e.contains("1 parameters"), "{e}");
    }

    #[test]
    fn unsupported_ops_rejected_at_parse_time() {
        let text = r#"
HloModule t

ENTRY main.3 {
  Arg_0.1 = f32[4]{0} parameter(0)
  ROOT custom-call.2 = f32[4]{0} custom-call(Arg_0.1), custom_call_target="foo"
}
"#;
        // Rejected at parse ("compile") time, naming the opcode, so a bad
        // artifact fails before any training loop starts.
        let e = Module::parse(text).unwrap_err().to_string();
        assert!(e.contains("custom-call"), "{e}");
    }

    #[test]
    fn canonical_text_with_typed_operands_parses() {
        // The canonical HLO printer prefixes operands with types and '%'.
        let text = r#"
HloModule t

ENTRY %main.4 (Arg_0.1: f32[2]) -> (f32[2]) {
  %Arg_0.1 = f32[2]{0} parameter(0)
  %add.2 = f32[2]{0} add(f32[2]{0} %Arg_0.1, f32[2]{0} %Arg_0.1)
  ROOT %tuple.3 = (f32[2]{0}) tuple(f32[2]{0} %add.2)
}
"#;
        let out = eval(text, &[&Literal::vec1(&[1.0f32, -3.0])]);
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![2.0, -6.0]);
    }
}
