//! Sharded-step-executor acceptance tests over the committed interpreter
//! fixtures — the `--step-jobs` analogue of the trial-engine gates in
//! tests/engine.rs, running everywhere with zero skips:
//!
//! 1. **Byte equality** — the same trial produces byte-identical
//!    canonical run records at `step_jobs = 1` and `step_jobs = 4`
//!    (deterministic block-order reduction), on both fixture models and
//!    under mid-plan block mixes (multi-rung ladders, padded tails,
//!    Oracle full-dataset scans, device updates).
//! 2. **Isolation** — a poisoned worker fails the *trial* with an error
//!    naming the block, instead of hanging or corrupting siblings.
//! 3. **Composition** — the engine's budget split: trial workers x step
//!    allowance never oversubscribes, and explicit `step_jobs` passes
//!    through the engine untouched.

mod common;

use divebatch::cluster::ClusterModel;
use divebatch::config::{DatasetSpec, RunSpec};
use divebatch::coordinator::{LrSchedule, Policy, StepExecutor, TrainConfig, Trainer};
use divebatch::data::{synthetic, SyntheticSpec};
use divebatch::engine::TrialRunner;
use divebatch::runtime::ExecCache;

fn synth_split(n: usize, seed: u64) -> (divebatch::Dataset, divebatch::Dataset) {
    synthetic::generate(&SyntheticSpec {
        n,
        d: 8,
        noise: 0.05,
        seed,
    })
    .split(0.8)
}

/// Run one config at an explicit step-jobs level; returns the canonical
/// record JSON.
fn canonical_at_step_jobs(mut cfg: TrainConfig, step_jobs: usize, n: usize, seed: u64) -> String {
    let rt = common::runtime();
    cfg.step_jobs = step_jobs;
    let (train, val) = synth_split(n, seed);
    let rec = Trainer::new(&rt, cfg, train, val, ClusterModel::a100x4(9, 1e3))
        .unwrap()
        .run()
        .unwrap()
        .record;
    rec.to_canonical_json().to_string()
}

/// The headline determinism gate: `--step-jobs 1` vs `--step-jobs 4`
/// byte-identical canonical records, across policies that exercise
/// multi-block plans (batches above the largest rung), instrumented and
/// plain epochs, and padded tails.
#[test]
fn step_jobs_records_byte_identical_1_vs_4() {
    let cases: Vec<(&str, TrainConfig)> = vec![
        (
            // Fixed batch 32 over ladder [4, 8]: 4 blocks of 8 per step.
            "fixed-multiblock",
            TrainConfig::new(
                "tinylogreg8",
                Policy::Fixed { m: 32 },
                LrSchedule::constant(0.3, false),
                4,
            ),
        ),
        (
            // DiveBatch growing past the ladder: plans go 1 -> many
            // blocks as the batch grows, instrumented every epoch.
            "divebatch-growing",
            TrainConfig::new(
                "tinylogreg8",
                Policy::DiveBatch {
                    m0: 4,
                    delta: 0.5,
                    m_max: 48,
                },
                LrSchedule::constant(0.3, true),
                5,
            ),
        ),
        (
            // Oracle: plain training steps + a full instrumented scan
            // through the same executor at every boundary.
            "oracle-scan",
            TrainConfig::new(
                "tinylogreg8",
                Policy::Oracle {
                    m0: 8,
                    delta: 0.5,
                    m_max: 32,
                },
                LrSchedule::constant(0.2, false),
                3,
            ),
        ),
        (
            // Wide-ladder fixture model: 64-row blocks + padded tails
            // (100 % 64 != 0), the perf_step bench's shape.
            "steplogreg-wide",
            TrainConfig::new(
                "steplogreg8",
                Policy::Fixed { m: 100 },
                LrSchedule::constant(0.1, false),
                3,
            ),
        ),
    ];
    for (tag, cfg) in cases {
        let serial = canonical_at_step_jobs(cfg.clone(), 1, 240, 17);
        let parallel = canonical_at_step_jobs(cfg, 4, 240, 17);
        assert_eq!(serial, parallel, "{tag}: records diverged across step-jobs levels");
    }
}

/// Device-update path under a parallel step executor: the fused update
/// consumes the folded gradient, so it must see the identical reduction.
#[test]
fn step_jobs_device_update_byte_identical() {
    let mut cfg = TrainConfig::new(
        "tinylogreg8",
        Policy::Fixed { m: 24 },
        LrSchedule::constant(0.2, false),
        3,
    );
    cfg.device_update = true;
    let serial = canonical_at_step_jobs(cfg.clone(), 1, 160, 5);
    let parallel = canonical_at_step_jobs(cfg, 4, 160, 5);
    assert_eq!(serial, parallel);
}

/// Lane counts that do not divide the block count (and exceed it) still
/// reduce identically.
#[test]
fn step_jobs_odd_lane_counts_agree() {
    let cfg = TrainConfig::new(
        "tinylogreg8",
        Policy::Fixed { m: 40 }, // 5 blocks of 8
        LrSchedule::constant(0.3, false),
        3,
    );
    let base = canonical_at_step_jobs(cfg.clone(), 1, 200, 23);
    for lanes in [2usize, 3, 8] {
        assert_eq!(
            base,
            canonical_at_step_jobs(cfg.clone(), lanes, 200, 23),
            "lanes={lanes}"
        );
    }
}

/// The canonical JSON carries the dispatch accounting (dp/pw) while
/// masking the lane-dependent utilization (pu) — so the fields exist
/// without breaking the byte-equality above.
#[test]
fn dispatch_fields_recorded_and_lane_utilization_masked() {
    let rt = common::runtime();
    let mut cfg = TrainConfig::new(
        "steplogreg8",
        Policy::Fixed { m: 100 }, // 1x64 + 4x8 + tail 4->8: waste > 0
        LrSchedule::constant(0.1, false),
        2,
    );
    cfg.step_jobs = 4;
    let (train, val) = synth_split(250, 31);
    let rec = Trainer::new(&rt, cfg, train, val, ClusterModel::a100x4(9, 1e3))
        .unwrap()
        .run()
        .unwrap()
        .record;
    for e in &rec.epochs {
        assert!(e.dispatches > 0);
        assert!((0.0..1.0).contains(&e.pad_waste), "{}", e.pad_waste);
        assert!(e.par_util > 0.0 && e.par_util <= 1.0, "{}", e.par_util);
    }
    assert!(rec.total_dispatches() > 0);
    // 200 train rows at m=100 over ladder [8, 64] pads the 36-row
    // remainder: waste must be visible.
    assert!(rec.mean_pad_waste() > 0.0);
    let canon = rec.to_canonical_json().to_string();
    assert!(canon.contains("\"dp\":"), "{canon}");
    assert!(canon.contains("\"pu\":0,"), "pu must be masked: {canon}");
    let summary = rec.summary_json().to_string();
    assert!(summary.contains("\"dispatches\":"), "{summary}");
    assert!(summary.contains("\"mean_pad_waste\":"), "{summary}");
}

/// Panic isolation at the trainer level: a worker poisoned mid-plan
/// (panicking executable path) fails the run with an error naming the
/// block — no hang, no torn parameter update — and the runtime stays
/// usable.  The panic is injected through the step executor directly
/// (the trainer's block closure runs arbitrary runtime calls; anything
/// in it may panic).
#[test]
fn poisoned_worker_fails_with_named_block_not_a_hang() {
    let step = StepExecutor::new(4);
    let err = step
        .run_blocks(6, |_, i| -> anyhow::Result<u64> {
            if i == 4 {
                panic!("interpreter exploded");
            }
            Ok(i as u64)
        })
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("step block 4 of 6") && msg.contains("panicked"),
        "{msg}"
    );

}

/// A policy that panics mid-run — the trial-level poisoning case: the
/// panic unwinds through an ACTIVE parallel step executor (its worker
/// pool must join, not deadlock), the engine captures it as a per-trial
/// error, the sibling trial completes, and the shared runtime survives.
#[derive(Clone, Copy, Debug)]
struct PanicAtEpoch(usize);

impl divebatch::BatchPolicy for PanicAtEpoch {
    fn kind(&self) -> &'static str {
        "panic-test"
    }
    fn label(&self) -> String {
        "PanicAtEpoch".into()
    }
    fn initial(&self) -> usize {
        16
    }
    fn on_epoch_end(
        &mut self,
        ctx: &divebatch::AdaptContext,
    ) -> Result<divebatch::Decision, divebatch::PolicyError> {
        if ctx.epoch >= self.0 {
            panic!("policy poisoned at epoch {}", ctx.epoch);
        }
        Ok(divebatch::Decision::new(16, divebatch::DiversityNeed::None))
    }
    fn render_spec(&self) -> String {
        "panic-test".into()
    }
    fn clone_box(&self) -> Box<dyn divebatch::BatchPolicy> {
        Box::new(*self)
    }
}

#[test]
fn poisoned_trial_is_isolated_with_step_pool_active() {
    let rt = common::runtime();
    let dataset = DatasetSpec::Synthetic(SyntheticSpec {
        n: 120,
        d: 8,
        noise: 0.05,
        seed: 3,
    });
    let mut poisoned = TrainConfig::new(
        "tinylogreg8",
        Box::new(PanicAtEpoch(1)) as Box<dyn divebatch::BatchPolicy>,
        LrSchedule::constant(0.2, false),
        4,
    );
    poisoned.step_jobs = 4; // the pool is live when the panic unwinds
    let healthy = TrainConfig::new(
        "tinylogreg8",
        Policy::Fixed { m: 16 },
        LrSchedule::constant(0.2, false),
        2,
    );
    let specs = vec![
        divebatch::TrialSpec {
            cfg: poisoned,
            dataset: dataset.clone(),
            flops_per_sample: 1e3,
            trial: 0,
        },
        divebatch::TrialSpec {
            cfg: healthy,
            dataset,
            flops_per_sample: 1e3,
            trial: 0,
        },
    ];
    let results = TrialRunner::new(2).run(&rt, &specs);
    assert_eq!(results.len(), 2);
    // A non-injected panic is presumed deterministic: the runner gives
    // it exactly one retry (2 attempts total), then reports the full
    // attempt history.
    match &results[0] {
        Err(divebatch::TrialError::Exhausted(attempts)) => {
            assert_eq!(attempts.len(), 2, "one retry for a compute panic");
            for a in attempts {
                match a {
                    divebatch::TrialError::Panicked(m) => {
                        assert!(m.contains("policy poisoned"), "{m}")
                    }
                    other => panic!("expected panic attempts, got {other:?}"),
                }
            }
        }
        other => panic!("expected an exhausted panic history, got {other:?}"),
    }
    assert!(results[1].is_ok(), "sibling trial must complete");
    // Runtime survives for subsequent work.
    assert!(rt.eval_exec("tinylogreg8", 4).is_ok());
}

/// Block failures surface deterministically: the lowest-index failing
/// block is reported at every lane count.
#[test]
fn block_errors_are_deterministic_across_lane_counts() {
    for lanes in [1usize, 2, 4] {
        let step = StepExecutor::new(lanes);
        let err = step
            .run_blocks(10, |_, i| -> anyhow::Result<()> {
                if i % 3 == 2 {
                    anyhow::bail!("bad block");
                }
                Ok(())
            })
            .unwrap_err();
        assert!(
            format!("{err:#}").contains("step block 2 of 10"),
            "lanes={lanes}: {err:#}"
        );
    }
}

/// Engine x executor composition: explicit step_jobs passes through the
/// engine, and the auto allowance divides the budget.
#[test]
fn engine_passes_step_budget_through() {
    // Budget arithmetic (pure).
    let r = TrialRunner::new(8);
    assert_eq!(r.step_allowance(2), 4);
    assert_eq!(r.step_allowance(8), 1);
    assert_eq!(TrialRunner::new(3).step_allowance(1), 3);

    // Explicit step_jobs through the engine matches a direct Trainer
    // run at the same level, byte for byte.
    let rt = common::runtime();
    let mut cfg = TrainConfig::new(
        "tinylogreg8",
        Policy::Fixed { m: 32 },
        LrSchedule::constant(0.3, false),
        3,
    );
    cfg.step_jobs = 4;
    let run = RunSpec {
        cfg: cfg.clone(),
        dataset: DatasetSpec::Synthetic(SyntheticSpec {
            n: 150,
            d: 8,
            noise: 0.05,
            seed: 11,
        }),
        trials: 2,
        flops_per_sample: 1e3,
    };
    let via_engine: Vec<String> = run
        .run_jobs(&rt, 2)
        .unwrap()
        .iter()
        .map(|r| r.to_canonical_json().to_string())
        .collect();
    let serial: Vec<String> = run
        .run_jobs(&rt, 1)
        .unwrap()
        .iter()
        .map(|r| r.to_canonical_json().to_string())
        .collect();
    assert_eq!(via_engine, serial);
}

/// The per-lane ExecCache hands out the SAME compiled object as the
/// central runtime cache (shared Arc), and caches the handle.
#[test]
fn exec_cache_shares_runtime_executables() {
    let rt = common::runtime();
    let mut cache = ExecCache::new();
    assert!(cache.is_empty());
    let a = cache.train(&rt, "tinylogreg8", true, 8).unwrap();
    let b = cache.train(&rt, "tinylogreg8", true, 8).unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b));
    let central = rt.train_exec("tinylogreg8", true, 8).unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &central));
    let e = cache.eval(&rt, "tinylogreg8", 4).unwrap();
    assert!(std::sync::Arc::ptr_eq(
        &e,
        &rt.eval_exec("tinylogreg8", 4).unwrap()
    ));
    assert_eq!(cache.len(), 2);
    // Distinct variants get distinct entries.
    let plain = cache.train(&rt, "tinylogreg8", false, 8).unwrap();
    assert!(!std::sync::Arc::ptr_eq(&a, &plain));
    assert_eq!(cache.len(), 3);
}

/// Warmup precompiles the full train/eval surface (both variants), so
/// parallel lanes never hit a first-compile guard mid-step.
#[test]
fn warmup_precompiles_both_train_variants() {
    let rt = common::runtime();
    assert_eq!(rt.stats().compiles, 0);
    rt.warmup("steplogreg8").unwrap();
    // ladder [8, 64] x {train_div, train_plain, eval} + update = 7.
    assert_eq!(rt.stats().compiles, 7);
    // Re-warmup is free (cache hits only).
    rt.warmup("steplogreg8").unwrap();
    assert_eq!(rt.stats().compiles, 7);
}
