//! Integration tests: the execution path over the committed fixture
//! artifacts (rust/tests/fixtures), which run on **every** machine via
//! the pure-Rust interpreter backend — no AOT build, no native XLA, no
//! skips.  These validate the full HLO text -> compile -> execute round
//! trip numerically against closed forms computed independently in Rust,
//! and against jax-evaluated goldens committed next to the fixtures.
//!
//! With `DIVEBATCH_TEST_ARTIFACTS=<dir>` (and the real xla_extension
//! binding linked), the `real_backend_*` tests additionally exercise the
//! tiny-artifact set (MLP, resnet) on a real PJRT backend as a
//! cross-check; the committed fixtures cover the same models on the
//! interpreter, so no model in the zoo depends on the real backend.

mod common;

use common::{real_runtime, runtime};
use divebatch::data::{Dataset, Labels};
use divebatch::util::json;

/// A tiny hand-made dataset for tinylogreg8 (d = 8).
fn toy_dataset(n: usize) -> Dataset {
    // Deterministic, hand-written values (no RNG: we recompute expected
    // losses below with plain Rust float math).
    let mut x = Vec::with_capacity(n * 8);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        for j in 0..8 {
            x.push(((i * 8 + j) as f32 * 0.37).sin());
        }
        y.push(((i * 7) % 2) as f32);
    }
    Dataset {
        x,
        y: Labels::Float(y),
        feat_shape: vec![8],
        num_classes: 2,
        name: "toy".into(),
    }
}

fn sigmoid(z: f64) -> f64 {
    1.0 / (1.0 + (-z).exp())
}

/// Reference logreg forward in Rust: per-sample (loss, correct, residual).
fn logreg_ref(params: &[f32], x: &[f32], y: f32) -> (f64, f64, f64) {
    let d = 8;
    let mut z = params[d] as f64; // bias
    for j in 0..d {
        z += params[j] as f64 * x[j] as f64;
    }
    // bce = logaddexp(z, 0) - z*y
    let loss = if z > 0.0 {
        z + (1.0 + (-z).exp()).ln()
    } else {
        (1.0 + z.exp()).ln()
    } - z * y as f64;
    let pred = if z > 0.0 { 1.0 } else { 0.0 };
    let correct = if pred == y as f64 { 1.0 } else { 0.0 };
    let residual = sigmoid(z) - y as f64;
    (loss, correct, residual)
}

fn demo_params() -> Vec<f32> {
    vec![0.3, -0.2, 0.05, 0.7, -0.4, 0.11, -0.09, 0.25, 0.02]
}

#[test]
fn manifest_lists_fixture_model() {
    let rt = runtime();
    let info = rt.model("tinylogreg8").unwrap();
    assert_eq!(info.param_count, 9);
    assert_eq!(info.ladder, vec![4, 8]);
    assert_eq!(info.feat_len(), 8);
    assert!(rt.has_execution_backend(), "interp backend must execute");
}

#[test]
fn eval_matches_rust_reference_numerics() {
    let rt = runtime();
    let ds = toy_dataset(8);
    let params = demo_params();
    let batch = ds.gather(&[0, 1, 2, 3, 4, 5, 6, 7], 8);
    let exec = rt.eval_exec("tinylogreg8", 8).unwrap();
    let out = exec.run_eval(&params, &batch).unwrap();

    let mut want_loss = 0.0;
    let mut want_correct = 0.0;
    let ys = match &ds.y {
        Labels::Float(v) => v.clone(),
        _ => unreachable!(),
    };
    for i in 0..8 {
        let (l, c, _) = logreg_ref(&params, &ds.x[i * 8..(i + 1) * 8], ys[i]);
        want_loss += l;
        want_correct += c;
    }
    assert!(
        (out.loss_sum - want_loss).abs() < 1e-4,
        "{} vs {want_loss}",
        out.loss_sum
    );
    assert_eq!(out.correct, want_correct);
}

#[test]
fn train_grad_matches_closed_form() {
    // grad = sum_i w_i * r_i * [x_i, 1] for logreg.
    let rt = runtime();
    let ds = toy_dataset(4);
    let params = demo_params();
    let batch = ds.gather(&[0, 1, 2, 3], 4);
    let exec = rt.train_exec("tinylogreg8", true, 4).unwrap();
    let out = exec.run_train(&params, &batch).unwrap();

    let ys = match &ds.y {
        Labels::Float(v) => v.clone(),
        _ => unreachable!(),
    };
    let mut want = vec![0.0f64; 9];
    let mut want_sq = 0.0;
    for i in 0..4 {
        let xi = &ds.x[i * 8..(i + 1) * 8];
        let (_, _, r) = logreg_ref(&params, xi, ys[i]);
        for j in 0..8 {
            want[j] += r * xi[j] as f64;
        }
        want[8] += r;
        let xnorm2: f64 = xi.iter().map(|&v| (v as f64) * (v as f64)).sum();
        want_sq += r * r * (xnorm2 + 1.0);
    }
    for (g, w) in out.grad_sum.iter().zip(&want) {
        assert!((*g as f64 - w).abs() < 1e-4, "{g} vs {w}");
    }
    assert!(
        (out.sqnorm_sum - want_sq).abs() / want_sq.max(1e-9) < 1e-3,
        "{} vs {want_sq}",
        out.sqnorm_sum
    );
}

#[test]
fn padding_rows_are_noops_through_execution() {
    let rt = runtime();
    let ds = toy_dataset(6);
    let params = demo_params();
    // 3 real rows padded to 4.
    let batch = ds.gather(&[0, 2, 4], 4);
    assert_eq!(batch.w, vec![1.0, 1.0, 1.0, 0.0]);
    let exec = rt.train_exec("tinylogreg8", true, 4).unwrap();
    let padded = exec.run_train(&params, &batch).unwrap();

    // Same three rows with a DIFFERENT garbage padding row but w=0:
    // outputs must match exactly.
    let mut batch2 = ds.gather(&[0, 2, 4], 4);
    for v in batch2.x[3 * 8..].iter_mut() {
        *v = 1e3;
    }
    let poked = exec.run_train(&params, &batch2).unwrap();
    assert_eq!(padded.loss_sum, poked.loss_sum);
    assert_eq!(padded.grad_sum, poked.grad_sum);
    assert_eq!(padded.sqnorm_sum, poked.sqnorm_sum);
}

#[test]
fn sample_sum_additivity_across_micro_batches() {
    let rt = runtime();
    let ds = toy_dataset(8);
    let params = demo_params();
    let full = rt
        .train_exec("tinylogreg8", true, 8)
        .unwrap()
        .run_train(&params, &ds.gather(&[0, 1, 2, 3, 4, 5, 6, 7], 8))
        .unwrap();
    let exec4 = rt.train_exec("tinylogreg8", true, 4).unwrap();
    let h1 = exec4
        .run_train(&params, &ds.gather(&[0, 1, 2, 3], 4))
        .unwrap();
    let h2 = exec4
        .run_train(&params, &ds.gather(&[4, 5, 6, 7], 4))
        .unwrap();
    assert!((full.loss_sum - (h1.loss_sum + h2.loss_sum)).abs() < 1e-4);
    assert!((full.sqnorm_sum - (h1.sqnorm_sum + h2.sqnorm_sum)).abs() < 1e-4);
    for (f, (a, b)) in full
        .grad_sum
        .iter()
        .zip(h1.grad_sum.iter().zip(&h2.grad_sum))
    {
        assert!((f - (a + b)).abs() < 1e-4);
    }
}

#[test]
fn div_and_plain_agree_on_shared_outputs() {
    let rt = runtime();
    let ds = toy_dataset(8);
    let params = demo_params();
    let b = ds.gather(&[0, 1, 2, 3, 4, 5, 6, 7], 8);
    let div = rt
        .train_exec("tinylogreg8", true, 8)
        .unwrap()
        .run_train(&params, &b)
        .unwrap();
    let plain = rt
        .train_exec("tinylogreg8", false, 8)
        .unwrap()
        .run_train(&params, &b)
        .unwrap();
    assert!((div.loss_sum - plain.loss_sum).abs() < 1e-5);
    assert_eq!(div.correct, plain.correct);
    assert_eq!(plain.sqnorm_sum, 0.0);
    assert!(div.sqnorm_sum > 0.0);
}

#[test]
fn update_executable_matches_rust_optimizer_rule() {
    let rt = runtime();
    let exec = rt.update_exec("tinylogreg8").unwrap();
    let p: usize = 9;
    let p0: Vec<f32> = (0..p).map(|i| (i as f32 * 0.1).sin()).collect();
    let v0: Vec<f32> = (0..p).map(|i| (i as f32 * 0.05).cos() * 0.01).collect();
    let g: Vec<f32> = (0..p).map(|i| (i as f32 * 0.2).cos()).collect();
    let (lr, mu, wd, m) = (0.1f32, 0.9f32, 5e-4f32, 64usize);
    let (dev_p, dev_v) = exec
        .run_update(&p0, &v0, &g, lr, mu, wd, 1.0 / m as f32)
        .unwrap();

    let mut want_p = p0.clone();
    let mut want_v = v0.clone();
    for i in 0..p {
        let eff = g[i] / m as f32 + wd * want_p[i];
        want_v[i] = mu * want_v[i] + eff;
        want_p[i] -= lr * want_v[i];
    }
    for i in 0..p {
        assert!((dev_p[i] - want_p[i]).abs() < 1e-5, "p[{i}]");
        assert!((dev_v[i] - want_v[i]).abs() < 1e-5, "v[{i}]");
    }
}

#[test]
fn executable_cache_reuses_compiles() {
    let rt = runtime();
    let a = rt.eval_exec("tinylogreg8", 4).unwrap();
    let before = rt.stats().compiles;
    let b = rt.eval_exec("tinylogreg8", 4).unwrap();
    assert_eq!(rt.stats().compiles, before);
    assert!(std::sync::Arc::ptr_eq(&a, &b));
    assert!(rt.cached_executables() >= 1);
}

#[test]
fn input_validation_errors_name_entry_and_tensor() {
    let rt = runtime();
    let ds = toy_dataset(4);
    let exec = rt.train_exec("tinylogreg8", true, 4).unwrap();
    // Wrong params length: the error names the entry, the tensor, and the
    // expected spec — actionable without a debugger.
    let short = vec![0.0f32; 5];
    let e = format!(
        "{:#}",
        exec.run_train(&short, &ds.gather(&[0, 1], 4)).unwrap_err()
    );
    assert!(
        e.contains("tinylogreg8") && e.contains("params") && e.contains('9'),
        "unactionable error: {e}"
    );
    // Wrong padding names the entry and both row counts.
    let params = demo_params();
    let e = format!(
        "{:#}",
        exec.run_train(&params, &ds.gather(&[0, 1], 2)).unwrap_err()
    );
    assert!(
        e.contains("tinylogreg8") && e.contains('2') && e.contains('4'),
        "unactionable error: {e}"
    );
    // Update-entry vector mismatch names the offending input.
    let upd = rt.update_exec("tinylogreg8").unwrap();
    let e = format!(
        "{:#}",
        upd.run_update(&params, &params[..5], &params, 0.1, 0.0, 0.0, 1.0)
            .unwrap_err()
    );
    assert!(e.contains("velocity"), "unactionable error: {e}");
    // Unknown model / entry.
    assert!(rt.model("nope").is_err());
    assert!(rt.entry("tinylogreg8", "train_div_b999").is_err());
}

#[test]
fn init_params_load_and_differ_by_seed() {
    let rt = runtime();
    let p0 = rt.manifest.load_init_params("tinylogreg8", 0).unwrap();
    let p1 = rt.manifest.load_init_params("tinylogreg8", 1).unwrap();
    assert_eq!(p0.len(), 9);
    assert_ne!(p0, p1);
    // Wrap-around beyond available seeds (3 emitted for the fixtures).
    let p3 = rt.manifest.load_init_params("tinylogreg8", 3).unwrap();
    assert_eq!(p0, p3);
}

#[test]
fn numerical_gradient_check_through_interpreter() {
    // Finite differences on the EVAL executable vs grad from TRAIN —
    // validates the whole HLO bridge end to end.
    let rt = runtime();
    let ds = toy_dataset(4);
    let params = demo_params();
    let batch = ds.gather(&[0, 1, 2, 3], 4);
    let train = rt.train_exec("tinylogreg8", false, 4).unwrap();
    let eval = rt.eval_exec("tinylogreg8", 4).unwrap();
    let grad = train.run_train(&params, &batch).unwrap().grad_sum;
    let eps = 1e-3f32;
    for i in [0usize, 3, 8] {
        let mut plus = params.clone();
        plus[i] += eps;
        let mut minus = params.clone();
        minus[i] -= eps;
        let lp = eval.run_eval(&plus, &batch).unwrap().loss_sum;
        let lm = eval.run_eval(&minus, &batch).unwrap().loss_sum;
        let fd = (lp - lm) / (2.0 * eps as f64);
        assert!(
            (grad[i] as f64 - fd).abs() < 5e-2 * fd.abs().max(1.0),
            "param {i}: grad {} vs fd {fd}",
            grad[i]
        );
    }
}

/// The anchor for the interpreter backend: every entry of every fixture
/// model, replayed over the committed jax-evaluated inputs/outputs
/// (rust/tests/fixtures/golden_entry_outputs.json, regenerated by
/// `python -m compile.fixtures`).  A numeric divergence between the
/// interpreter and the Python reference fails here, entry by entry.
#[test]
fn interpreter_matches_python_golden() {
    let rt = runtime();
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/golden_entry_outputs.json"
    );
    let text = std::fs::read_to_string(path).expect("committed golden file");
    let doc = json::parse(&text).unwrap();
    let models = doc.req("models").unwrap().as_obj().unwrap();
    for required in ["tinylogreg8", "steplogreg8", "tinymlp8", "tinyresnet4"] {
        assert!(
            models.contains_key(required),
            "expected goldens for fixture model {required}"
        );
    }
    let entries: Vec<(&String, &String, &json::Json)> = models
        .iter()
        .flat_map(|(model, doc)| {
            let e = doc.as_obj().expect("model goldens are an object");
            assert!(e.len() >= 7, "{model}: expected all entries covered");
            e.iter().map(move |(key, case)| (model, key, case))
        })
        .collect();
    assert!(entries.len() >= 28, "expected every fixture entry covered");

    let to_f32 = |j: &json::Json| -> Vec<f32> {
        j.as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect()
    };
    let close = |got: f64, want: f64, tag: &str| {
        assert!(
            (got - want).abs() <= 1e-4 * (1.0 + want.abs()),
            "{tag}: interpreter {got} vs python {want}"
        );
    };

    for (model, key, case) in entries {
        let inputs: Vec<Vec<f32>> = case.req_arr("inputs").unwrap().iter().map(to_f32).collect();
        let outputs: Vec<Vec<f32>> = case
            .req_arr("outputs")
            .unwrap()
            .iter()
            .map(to_f32)
            .collect();
        if key == "update" {
            let exec = rt.update_exec(model).unwrap();
            let s = &inputs[3];
            let (p, v) = exec
                .run_update(&inputs[0], &inputs[1], &inputs[2], s[0], s[1], s[2], s[3])
                .unwrap();
            for (i, (&got, &want)) in p.iter().zip(&outputs[0]).enumerate() {
                close(got as f64, want as f64, &format!("{model} update p[{i}]"));
            }
            for (i, (&got, &want)) in v.iter().zip(&outputs[1]).enumerate() {
                close(got as f64, want as f64, &format!("{model} update v[{i}]"));
            }
            continue;
        }
        let m = inputs[2].len();
        // Labels ride in the batch field matching the entry's declared
        // parameter dtype (tinyresnet4 takes s32 class ids, the rest f32).
        let spec = rt.manifest.model(model).unwrap().entry(key).unwrap();
        let (y_f32, y_i32) = if spec.inputs[2].dtype == divebatch::runtime::Dtype::S32 {
            (Vec::new(), inputs[2].iter().map(|&v| v as i32).collect())
        } else {
            (inputs[2].clone(), Vec::new())
        };
        let batch = divebatch::Batch {
            x: inputs[1].clone(),
            y_f32,
            y_i32,
            w: inputs[3].clone(),
            real: inputs[3].iter().filter(|&&w| w > 0.0).count(),
            pad_to: m,
        };
        let exec = rt.entry(model, key).unwrap();
        if key.starts_with("eval") {
            let out = exec.run_eval(&inputs[0], &batch).unwrap();
            close(out.loss_sum, outputs[0][0] as f64, &format!("{model}/{key} loss"));
            close(out.correct, outputs[1][0] as f64, &format!("{model}/{key} correct"));
        } else {
            let out = exec.run_train(&inputs[0], &batch).unwrap();
            close(out.loss_sum, outputs[0][0] as f64, &format!("{model}/{key} loss"));
            close(out.correct, outputs[1][0] as f64, &format!("{model}/{key} correct"));
            for (i, (&got, &want)) in out.grad_sum.iter().zip(&outputs[2]).enumerate() {
                close(got as f64, want as f64, &format!("{model}/{key} grad[{i}]"));
            }
            close(
                out.sqnorm_sum,
                outputs[3][0] as f64,
                &format!("{model}/{key} sqnorm"),
            );
        }
    }
}

// ---------------------------------------------------------------- opt-in
// Real-backend extras: run only with DIVEBATCH_TEST_ARTIFACTS=<dir> (and
// the real xla_extension binding linked).  The interpreter fixtures now
// ship the full tiny model zoo (logreg, MLP, conv resnet); these extras
// re-run the resnet path against a real PJRT backend as a cross-check.

#[test]
fn real_backend_manifest_lists_tiny_models() {
    let Some(rt) = real_runtime() else {
        return; // opt-in extra, not a gate: the fixture suite above ran.
    };
    for name in ["tinylogreg8", "tinymlp8", "tinyresnet4"] {
        let info = rt.model(name).unwrap();
        assert!(!info.ladder.is_empty());
        assert!(info.param_count > 0);
    }
    assert_eq!(rt.model("tinylogreg8").unwrap().param_count, 9);
}

#[test]
fn real_backend_resnet_entries_execute() {
    let Some(rt) = real_runtime() else {
        return;
    };
    let info = rt.model("tinyresnet4").unwrap().clone();
    assert_eq!(info.input_shape, vec![8, 8, 3]);
    let n = 4;
    let feat = 8 * 8 * 3;
    let mut x = vec![0.0f32; n * feat];
    for (i, v) in x.iter_mut().enumerate() {
        *v = ((i as f32) * 0.01).sin();
    }
    let ds = Dataset {
        x,
        y: Labels::Int(vec![0, 1, 2, 3]),
        feat_shape: vec![8, 8, 3],
        num_classes: 4,
        name: "imgtoy".into(),
    };
    let params = rt.manifest.load_init_params("tinyresnet4", 0).unwrap();
    let batch = ds.gather(&[0, 1, 2, 3], 4);
    let out = rt
        .train_exec("tinyresnet4", true, 4)
        .unwrap()
        .run_train(&params, &batch)
        .unwrap();
    assert!(out.loss_sum.is_finite() && out.loss_sum > 0.0);
    assert!(out.sqnorm_sum > 0.0);
    assert_eq!(out.grad_sum.len(), info.param_count);
    assert!((0.0..=4.0).contains(&out.correct));
    // Cross-entropy at init should be near ln(4) per sample.
    let per_sample = out.loss_sum / 4.0;
    assert!((per_sample - (4.0f64).ln()).abs() < 1.0, "{per_sample}");
}
