//! Robustness suite for the two parsers a `divebatch serve` process
//! exposes to untrusted bytes: the in-tree JSON parser
//! ([`divebatch::util::json`], every request body) and the vendored HLO
//! text parser (`vendor/xla`, every artifact a server operator points
//! the runtime at).  Property-tested via the in-tree mini-proptest
//! ([`divebatch::util::prop::forall`], seeded by `DIVEBATCH_PROP_SEED`):
//! arbitrary bytes, truncations and point mutations must come back as
//! typed errors — never a panic, never unbounded recursion or
//! allocation.
//!
//! Each property wraps the parse in `catch_unwind`, so a regression
//! shows up as a shrunk counterexample input, not a test harness abort.

use std::panic::{catch_unwind, AssertUnwindSafe};

use divebatch::util::json;
use divebatch::util::prop::forall;

/// A committed HLO fixture — real parser input to truncate and mutate.
const HLO_FIXTURE: &str = concat!(
    env!("CARGO_MANIFEST_DIR"),
    "/tests/fixtures/artifacts/tinylogreg8/train_plain_b4.hlo.txt"
);

/// True iff `f` returns (any result) without panicking.
fn no_panic<F: FnOnce()>(f: F) -> bool {
    catch_unwind(AssertUnwindSafe(f)).is_ok()
}

/// Largest char-boundary cut point <= `at`.
fn boundary_cut(text: &str, at: usize) -> usize {
    let mut cut = at.min(text.len());
    while !text.is_char_boundary(cut) {
        cut -= 1;
    }
    cut
}

/// Compile `text` through the full serve-side HLO path: wrap the text,
/// build the computation, compile on the interpreter backend.  Ok and
/// Err are both acceptable; the property under test is "no panic".
fn compile_hlo(text: &str) {
    let proto = xla::HloModuleProto::from_text(text);
    let comp = xla::XlaComputation::from_proto(&proto);
    let _ = xla::PjRtClient::interp().compile(&comp);
}

// --------------------------------------------------------------- JSON

#[test]
fn json_parse_survives_arbitrary_bytes() {
    forall(
        300,
        |r| {
            let len = r.below(64) as usize;
            (0..len).map(|_| r.below(256)).collect::<Vec<u64>>()
        },
        |bytes| {
            let raw: Vec<u8> = bytes.iter().map(|&b| b as u8).collect();
            let text = String::from_utf8_lossy(&raw).into_owned();
            no_panic(|| {
                let _ = json::parse(&text);
            })
        },
    );
}

#[test]
fn json_parse_survives_truncations_of_valid_documents() {
    // A document exercising every construct: nesting, escapes, numbers
    // in exotic shapes, unicode.
    let doc = r#"{"a":[1,-2.5e-3,true,null,"x\nyé"],"b":{"c":{"d":[{"e":1e308}]}},"f":"ümlaut"}"#;
    assert!(json::parse(doc).is_ok(), "base document must parse");
    forall(
        200,
        |r| r.below(doc.len() as u64 + 1) as usize,
        |&at| {
            let cut = boundary_cut(doc, at);
            no_panic(|| {
                let _ = json::parse(&doc[..cut]);
            })
        },
    );
}

#[test]
fn json_parse_survives_point_mutations_of_valid_documents() {
    let doc = r#"{"model":"tinylogreg8","policy":"sgd:m=4","epochs":2,"dataset":{"kind":"synthetic","n":40,"d":8}}"#;
    forall(
        300,
        |r| (r.below(doc.len() as u64), 32 + r.below(95)),
        |&(pos, ch)| {
            let mut bytes = doc.as_bytes().to_vec();
            bytes[pos as usize] = ch as u8; // printable ASCII substitution
            let text = String::from_utf8(bytes).expect("ascii stays utf-8");
            no_panic(|| {
                let _ = json::parse(&text);
            })
        },
    );
}

#[test]
fn json_depth_bound_is_an_error_not_a_stack_overflow() {
    // 100k opens: must come back as a typed depth error immediately.
    let deep = "[".repeat(100_000);
    match json::parse(&deep) {
        Err(e) => assert!(
            e.message.contains("depth") || e.message.contains("nest"),
            "depth rejection should say so: {e}"
        ),
        Ok(_) => panic!("unterminated 100k-deep array cannot be valid"),
    }
    // Mixed nesting with bodies, beyond the bound.
    let deep = format!("{}1{}", "[{\"k\":".repeat(500), "}]".repeat(500));
    assert!(json::parse(&deep).is_err(), "beyond MAX_DEPTH must error");
    // ...and a comfortably-deep valid document still parses.
    let ok = format!("{}1{}", "[".repeat(100), "]".repeat(100));
    assert!(json::parse(&ok).is_ok(), "depth 100 is within bounds");
}

// ---------------------------------------------------------------- HLO

#[test]
fn hlo_compile_survives_truncations_of_a_real_module() {
    let text = std::fs::read_to_string(HLO_FIXTURE).expect("committed fixture");
    // Whole-file sanity: the untruncated module must still compile.
    assert!(
        catch_unwind(AssertUnwindSafe(|| {
            let proto = xla::HloModuleProto::from_text(&text);
            let comp = xla::XlaComputation::from_proto(&proto);
            xla::PjRtClient::interp().compile(&comp).is_ok()
        }))
        .unwrap_or(false),
        "fixture module must compile cleanly"
    );
    forall(
        150,
        |r| r.below(text.len() as u64 + 1) as usize,
        |&at| {
            let cut = boundary_cut(&text, at);
            no_panic(|| compile_hlo(&text[..cut]))
        },
    );
}

#[test]
fn hlo_compile_survives_point_mutations_of_a_real_module() {
    let text = std::fs::read_to_string(HLO_FIXTURE).expect("committed fixture");
    forall(
        200,
        |r| (r.below(text.len() as u64), 32 + r.below(95)),
        |&(pos, ch)| {
            let mut bytes = text.as_bytes().to_vec();
            bytes[pos as usize] = ch as u8;
            let mutated = String::from_utf8(bytes).expect("ascii fixture stays utf-8");
            no_panic(|| compile_hlo(&mutated))
        },
    );
}

#[test]
fn hlo_compile_rejects_hostile_modules_with_errors_not_panics() {
    // Hand-picked adversarial inputs: each historically a panic class
    // (slicing, indexing, or arithmetic overflow) somewhere in a naive
    // HLO text parser.
    // (text, must_reject): every entry must not panic; the flagged ones
    // must additionally come back as typed compile errors.
    let hostile: &[(&str, bool)] = &[
        ("", true),
        ("HloModule", true),
        ("HloModule x", true),
        ("ENTRY main {", true),
        ("HloModule x\n\nENTRY main {\n}", true),
        // Shape element-count overflow: usize::MAX x 2 elements — the
        // parse-time checked_mul guard must catch this, not a debug
        // overflow panic in `Shape::elements`.
        (
            "HloModule x\n\nENTRY main.1 {\n  ROOT c.1 = f32[18446744073709551615,2] constant(0)\n}",
            true,
        ),
        // Huge-but-individually-parseable dims whose product explodes.
        (
            "HloModule x\n\nENTRY main.1 {\n  ROOT c.1 = f32[4294967295,4294967295] constant(0)\n}",
            true,
        ),
        // Unbalanced/garbled operator syntax: no panic required; typed
        // rejection expected but the exact error path may vary.
        ("HloModule x\n\nENTRY main.1 {\n  ROOT a.1 = f32[] add(\n}", false),
        ("HloModule x\n\nENTRY main.1 {\n  = = =\n}", false),
        // Parameter index out of range (may be deferred to execution).
        ("HloModule x\n\nENTRY main.1 {\n  ROOT p.1 = f32[2] parameter(99)\n}", false),
    ];
    for &(text, must_reject) in hostile {
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            let proto = xla::HloModuleProto::from_text(text);
            let comp = xla::XlaComputation::from_proto(&proto);
            xla::PjRtClient::interp().compile(&comp).err()
        }));
        match outcome {
            Err(_) => panic!("hostile module panicked the compiler: {text:?}"),
            Ok(Some(_err)) => {} // typed rejection
            Ok(None) => assert!(!must_reject, "hostile module compiled: {text:?}"),
        }
    }
}
