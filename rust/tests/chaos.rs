//! Chaos suite (the fault-tolerance layer's acceptance gate): every
//! fault-injection scope produces a *typed* failure — never a hang, an
//! escaped panic, or a corrupted artifact — retry/backoff attempt
//! counts are deterministic under a fixed fault seed, and a sweep
//! SIGKILLed mid-flight resumes to a byte-identical journal.
//!
//! In-process tests install their plan through
//! [`divebatch::fault::FaultGuard`], which serializes them on a
//! process-wide gate (the plan is global state).  The subprocess tests
//! drive the shipped binary through `--inject` / `DIVEBATCH_FAULTS`
//! instead and need no gate.

mod common;

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::time::{Duration, Instant};

use divebatch::config::rescache::ResultsCache;
use divebatch::config::{flops_per_sample, DatasetSpec};
use divebatch::coordinator::{LrSchedule, PolicyRegistry, TrainConfig};
use divebatch::data::SyntheticSpec;
use divebatch::fault::{self, Clock, FaultGuard, FaultPlan, SimClock};
use divebatch::metrics::EpochRecord;
use divebatch::pool::{JobError, WorkerPool};
use divebatch::{
    ClusterSpec, RetryPolicy, RunRecord, ServeConfig, Server, TrialError, TrialRunner, TrialSpec,
};

// ------------------------------------------------------------ helpers

fn plan(spec: &str, seed: u64) -> FaultPlan {
    FaultPlan::parse(spec, seed).expect("chaos plan parses")
}

/// Fresh scratch directory under the system tmpdir.
fn tmp(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("divebatch-chaos-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    dir
}

/// The same tiny trial the server equivalence suite uses: tinylogreg8
/// on a 40x8 synthetic draw, one epoch — fast enough to retry thrice.
fn trial(seed: u64) -> TrialSpec {
    let policy = PolicyRegistry::builtin().parse("sgd:m=4").expect("policy");
    let schedule = LrSchedule {
        base: 0.1,
        decay: 0.75,
        every: 20,
        rescale_with_batch: false,
    };
    let mut cfg = TrainConfig::new("tinylogreg8", policy, schedule, 1);
    cfg.cluster = ClusterSpec {
        workers: 4,
        div_overhead: 0.9,
        ..ClusterSpec::default()
    };
    cfg.verbose = false;
    TrialSpec {
        cfg,
        dataset: DatasetSpec::Synthetic(SyntheticSpec {
            n: 40,
            d: 8,
            noise: 0.1,
            seed: 1000,
        }),
        flops_per_sample: flops_per_sample("tinylogreg8"),
        trial: seed,
    }
}

/// A synthetic cache payload (the cache never inspects records).
fn record(seed: u64) -> RunRecord {
    let mut r = RunRecord::new("chaos", "m", "sgd", "d", seed);
    r.epochs.push(EpochRecord {
        epoch: 0,
        batch_size: 8,
        lr: 0.1,
        steps: 4,
        train_loss: 1.0,
        train_acc: 0.5,
        val_loss: 1.0,
        val_acc: 0.5,
        delta_hat: None,
        n_delta: None,
        exact_delta: None,
        wall_s: 7.0,
        sim_s: 0.1,
        cum_wall_s: 7.0,
        cum_sim_s: 0.1,
        mem_mb: 1.0,
        dispatches: 1,
        pad_waste: 0.0,
        par_util: 1.0,
    });
    r
}

/// Every surviving file in a cache/journal scratch dir must be a
/// published entry — no half-written tmp files, no abandoned locks.
fn assert_no_debris(dir: &Path) {
    for e in std::fs::read_dir(dir).expect("scan scratch dir").flatten() {
        let name = e.file_name().to_string_lossy().into_owned();
        assert!(
            name.ends_with(".json") || name.ends_with(".journal"),
            "debris left behind: {name}"
        );
    }
}

// ------------------------------------ trial boundary: panic and error

#[test]
fn injected_trial_panic_exhausts_with_deterministic_attempts() {
    let _g = FaultGuard::install(plan("trial-panic@t0", 0));
    let rt = common::runtime();
    let sim = SimClock::new();
    let runner = TrialRunner::new(1).with_clock(Clock::Sim(sim.clone()));
    let res = runner.run(&rt, &[trial(0), trial(1)]);
    match &res[0] {
        Err(TrialError::Exhausted(attempts)) => {
            assert_eq!(attempts.len(), 3, "default policy: exactly 3 attempts");
            for a in attempts {
                match a {
                    TrialError::Panicked(m) => {
                        assert!(m.contains("divebatch-fault"), "attempt not injected: {m}")
                    }
                    other => panic!("expected a captured panic, got {other}"),
                }
            }
        }
        Err(other) => panic!("expected exhausted attempt history, got {other}"),
        Ok(_) => panic!("trial 0 must fail under trial-panic@t0"),
    }
    assert!(res[1].is_ok(), "the fault is scoped to trial 0");
    assert_eq!(
        sim.slept(),
        vec![Duration::from_millis(50), Duration::from_millis(100)],
        "backoff schedule is deterministic on the sim clock"
    );
}

#[test]
fn transient_trial_error_recovers_within_the_retry_budget() {
    let _g = FaultGuard::install(plan("trial-error@t0:2", 0));
    let rt = common::runtime();
    let sim = SimClock::new();
    let runner = TrialRunner::new(1).with_clock(Clock::Sim(sim.clone()));
    let res = runner.run(&rt, &[trial(0)]);
    assert!(
        res[0].is_ok(),
        "two injected failures fit inside the 3-attempt budget"
    );
    assert_eq!(
        sim.slept(),
        vec![Duration::from_millis(50), Duration::from_millis(100)]
    );
}

#[test]
fn retry_disabled_fails_fast_with_a_typed_error() {
    let _g = FaultGuard::install(plan("trial-error@t0", 0));
    let rt = common::runtime();
    let sim = SimClock::new();
    let runner = TrialRunner::new(1)
        .with_retry(RetryPolicy::none())
        .with_clock(Clock::Sim(sim.clone()));
    let res = runner.run(&rt, &[trial(0)]);
    match &res[0] {
        Err(TrialError::Failed(m)) => {
            assert!(m.contains("injected trial-error"), "untyped failure: {m}")
        }
        Err(other) => panic!("expected the raw injected failure, got {other}"),
        Ok(_) => panic!("trial 0 must fail under trial-error@t0"),
    }
    assert!(sim.slept().is_empty(), "no backoff without retries");
}

// ----------------------------------------------- step-block dispatch

#[test]
fn injected_step_block_panic_is_a_typed_block_failure() {
    let _g = FaultGuard::install(plan("step-panic@t0:b0", 0));
    let rt = common::runtime();
    let runner = TrialRunner::new(1).with_retry(RetryPolicy::none());
    let res = runner.run(&rt, &[trial(0)]);
    match &res[0] {
        Err(TrialError::Failed(m)) => {
            assert!(m.contains("step block 0"), "block not annotated: {m}");
            assert!(m.contains("divebatch-fault"), "injection not tagged: {m}");
        }
        Err(other) => panic!("expected a typed block failure, got {other}"),
        Ok(_) => panic!("trial 0 must fail under step-panic@t0:b0"),
    }
}

// ------------------------------------------------------------- stall

#[test]
fn stall_injection_delays_but_the_trial_still_succeeds() {
    let _g = FaultGuard::install(plan("stall@t0:40ms:2", 0));
    let rt = common::runtime();
    let runner = TrialRunner::new(1).with_retry(RetryPolicy::none());
    let t0 = Instant::now();
    let res = runner.run(&rt, &[trial(0)]);
    assert!(res[0].is_ok(), "a stall is a delay, not a failure");
    assert!(
        t0.elapsed() >= Duration::from_millis(70),
        "two 40ms stalls must be observable: {:?}",
        t0.elapsed()
    );
}

// ------------------------------------------------- results-cache I/O

#[test]
fn injected_store_errors_are_retried_inside_the_cache() {
    let dir = tmp("cache-retry");
    let cache = ResultsCache::new(&dir);
    let _g = FaultGuard::install(plan("io-error@store:2", 0));
    cache
        .store("k", &[record(1)])
        .expect("2 injected failures fit inside the cache's 3 store attempts");
    assert_eq!(cache.load("k", 1).map(|r| r.len()), Some(1));
    assert_no_debris(&dir);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn store_exhaustion_is_typed_and_leaves_no_debris() {
    let dir = tmp("cache-exhaust");
    let cache = ResultsCache::new(&dir);
    // Budget 9 = exactly three failing store calls (3 attempts each),
    // then the next call goes through — deterministic accounting.
    let _g = FaultGuard::install(plan("io-error@store:9", 0));
    for call in 0..3 {
        let err = cache
            .store("k", &[record(1)])
            .expect_err("budget covers all 3 attempts of this call");
        assert!(fault::is_injected(&err), "call {call} not typed: {err:#}");
        assert_no_debris(&dir);
    }
    cache.store("k", &[record(1)]).expect("budget is spent");
    assert_eq!(cache.load("k", 1).map(|r| r.len()), Some(1));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn injected_load_errors_degrade_to_a_counted_miss() {
    let dir = tmp("cache-load");
    let cache = ResultsCache::new(&dir);
    let _g = FaultGuard::install(plan("io-error@load:1", 0));
    cache.store("k", &[record(1)]).expect("stores are unaffected");
    assert!(
        cache.load("k", 1).is_none(),
        "an injected load fault is a miss, not a panic"
    );
    assert_eq!(
        cache.load("k", 1).map(|r| r.len()),
        Some(1),
        "the entry itself is intact once the budget is spent"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite: concurrent store/load/evict under probabilistic injected
/// I/O errors never panics, never corrupts an entry, and never leaks a
/// tmp file or lock (two seeded rounds, each 4 threads x 10 ops on a
/// 4-entry cache, so eviction and the dir lock are contended).
#[test]
fn concurrent_cache_chaos_preserves_invariants() {
    for seed in [3u64, 17] {
        let dir = tmp(&format!("cache-chaos-{seed}"));
        let cache = ResultsCache::with_limits(&dir, 4, 0);
        let g = FaultGuard::install(plan(
            "io-error@store:p0.5:12,io-error@load:p0.5:12",
            seed,
        ));
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let cache = &cache;
                s.spawn(move || {
                    for i in 0..10u64 {
                        let key = format!("k{}", (t * 7 + i) % 6);
                        // Both outcomes are legal under injection; the
                        // invariants below are what must hold.
                        let _ = cache.store(&key, &[record(t * 100 + i)]);
                        let _ = cache.load(&key, 1);
                    }
                });
            }
        });
        // Each rule fires at most 12 times, so a bounded number of
        // further calls must drain any remaining budget and succeed.
        let stored = (0..8).any(|_| cache.store("final", &[record(9)]).is_ok());
        assert!(stored, "store must succeed once the fire budget drains");
        let loaded = (0..16).find_map(|_| cache.load("final", 1));
        assert_eq!(loaded.map(|r| r.len()), Some(1));
        assert_no_debris(&dir);
        let entries: Vec<_> = std::fs::read_dir(&dir)
            .expect("scan")
            .flatten()
            .filter(|e| e.path().extension().is_some_and(|x| x == "json"))
            .collect();
        assert!(entries.len() <= 4, "eviction cap held: {}", entries.len());
        for e in &entries {
            let text = std::fs::read_to_string(e.path()).expect("entry readable");
            let json = divebatch::util::json::parse(&text)
                .unwrap_or_else(|err| panic!("corrupt entry {:?}: {err}", e.path()));
            assert!(json.as_arr().is_some(), "entry is not a record array");
        }
        drop(g);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

// --------------------------------------------------- worker-pool lane

#[test]
fn lane_panic_is_contained_and_the_pool_respawns() {
    let _g = FaultGuard::install(plan("lane-panic@w1:1", 0));
    let pool = WorkerPool::new(3);
    let out = pool.scatter(64, |_lane, i| {
        std::thread::sleep(Duration::from_millis(1));
        Ok(i * 2)
    });
    assert_eq!(out.len(), 64, "every claimed item is accounted for");
    let dead: Vec<&JobError> = out.iter().filter_map(|r| r.as_ref().err()).collect();
    assert_eq!(dead.len(), 1, "exactly one item dies with its lane");
    assert!(
        matches!(dead[0], JobError::Panicked(_)),
        "the lost item is a typed panic: {}",
        dead[0]
    );
    // The worker thread finishes unwinding shortly after the scatter.
    let t0 = Instant::now();
    while pool.live_lanes() != 2 && t0.elapsed() < Duration::from_secs(5) {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(pool.live_lanes(), 2, "lane 1's thread died by injection");
    let again = pool.scatter(64, |_lane, i| Ok(i));
    assert!(again.iter().all(|r| r.is_ok()), "post-respawn scatter is clean");
    assert_eq!(pool.live_lanes(), 3, "the next scatter respawned the lane");
}

// ----------------------------------------------- server connection

#[test]
fn dropped_connection_is_scoped_and_the_server_recovers() {
    let _g = FaultGuard::install(plan("conn-drop@c0", 0));
    let handle =
        Server::spawn(ServeConfig::new("127.0.0.1:0", common::fixtures_dir())).expect("spawn");
    let addr = handle.addr();

    // Connection 0: accepted, then dropped before a single byte.
    let mut s = TcpStream::connect(addr).expect("connect");
    let _ = s.write_all(b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
    let mut raw = String::new();
    let dropped = match s.read_to_string(&mut raw) {
        Ok(_) => raw.is_empty(),
        Err(_) => true, // reset by peer is also a drop
    };
    assert!(dropped, "connection 0 must be dropped, got: {raw:?}");

    // Connection 1: unaffected.
    let mut s = TcpStream::connect(addr).expect("reconnect");
    s.write_all(b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n")
        .expect("write");
    let mut raw = String::new();
    s.read_to_string(&mut raw).expect("read");
    assert!(
        raw.starts_with("HTTP/1.1 200"),
        "the drop is scoped to connection 0: {raw:?}"
    );
    handle.stop().expect("graceful stop");
}

// ------------------------------------------- subprocess: CLI --inject

fn divebatch_cmd() -> Command {
    let mut c = Command::new(env!("CARGO_BIN_EXE_divebatch"));
    // Never inherit ambient chaos into a controlled subprocess.
    c.env_remove("DIVEBATCH_FAULTS").env_remove("DIVEBATCH_FAULT_SEED");
    c.stdout(Stdio::piped()).stderr(Stdio::piped());
    c
}

fn sweep_args(extra: &[&str]) -> Vec<String> {
    let mut v: Vec<String> = [
        "sweep",
        "tinylogreg8",
        "--dataset",
        "synthetic",
        "--n",
        "40",
        "--dim",
        "8",
        "--epochs",
        "1",
        "--policies",
        "sgd:m=4;sgd:m=8",
        "--seeds",
        "3",
        "--jobs",
        "1",
        "--quiet",
        "--artifacts",
        common::fixtures_dir(),
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    v.extend(extra.iter().map(|s| s.to_string()));
    v
}

#[test]
fn cli_inject_fails_the_targeted_trial_and_exits_nonzero() {
    let out = divebatch_cmd()
        .args(sweep_args(&["--inject", "trial-panic@t1", "--seeds", "2"]))
        .output()
        .expect("run divebatch sweep");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        !out.status.success(),
        "a failed trial must fail the sweep: {stderr}"
    );
    assert!(stderr.contains("trial FAILED"), "no typed report: {stderr}");
    assert!(
        stderr.contains("trials failed"),
        "no failure summary: {stderr}"
    );
    assert!(
        stderr.contains("trial done"),
        "unfaulted trials still complete: {stderr}"
    );
}

// ------------------------------- subprocess: SIGKILL, resume, verify

/// The tentpole's acceptance gate: SIGKILL a journaling sweep
/// mid-flight, resume it, and require the journal to be byte-identical
/// to an uninterrupted run's.
#[test]
fn sigkill_mid_sweep_then_resume_is_byte_identical() {
    let dir = tmp("sigkill");
    let base = dir.join("base.journal");
    let killed = dir.join("killed.journal");

    // Uninterrupted reference run.
    let out = divebatch_cmd()
        .args(sweep_args(&["--journal", base.to_str().unwrap()]))
        .output()
        .expect("baseline sweep");
    assert!(
        out.status.success(),
        "baseline sweep failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let base_bytes = std::fs::read(&base).expect("baseline journal exists");

    // Interrupted run: stalls slow every injection point enough to
    // land a SIGKILL after the first completed trial.
    let mut child = divebatch_cmd()
        .args(sweep_args(&["--journal", killed.to_str().unwrap()]))
        .env("DIVEBATCH_FAULTS", "stall@*:40ms")
        .spawn()
        .expect("spawn sweep to kill");
    let t0 = Instant::now();
    loop {
        let recorded = std::fs::read_to_string(&killed)
            .map(|s| s.lines().filter(|l| !l.trim().is_empty()).count())
            .unwrap_or(0);
        if recorded >= 2 {
            // Header plus at least one trial: kill mid-sweep.
            let _ = child.kill(); // SIGKILL on unix
            break;
        }
        if child.try_wait().expect("poll child").is_some() {
            break; // finished before we could kill it; resume is a no-op
        }
        assert!(
            t0.elapsed() < Duration::from_secs(120),
            "sweep never journaled a trial"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    let _ = child.wait();

    // Resume from the truncated journal, no faults this time.
    let out = divebatch_cmd()
        .args(sweep_args(&["--resume", killed.to_str().unwrap()]))
        .output()
        .expect("resume sweep");
    assert!(
        out.status.success(),
        "resume failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );

    let killed_bytes = std::fs::read(&killed).expect("resumed journal exists");
    assert_eq!(
        killed_bytes.len(),
        base_bytes.len(),
        "resumed journal length differs from the uninterrupted run"
    );
    assert!(
        killed_bytes == base_bytes,
        "resumed journal is not byte-identical to the uninterrupted run"
    );
    // 1 header + 2 policies x 3 seeds.
    let lines = String::from_utf8(base_bytes).expect("journal is utf-8");
    assert_eq!(lines.lines().count(), 7, "journal records every trial");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A resume against a *different* sweep spec must be refused — the
/// journal's fingerprint pins the exact trial set.
#[test]
fn resume_refuses_a_mismatched_sweep_spec() {
    let dir = tmp("fingerprint");
    let journal = dir.join("sweep.journal");
    let out = divebatch_cmd()
        .args(sweep_args(&["--journal", journal.to_str().unwrap()]))
        .output()
        .expect("journaled sweep");
    assert!(out.status.success());
    // Same journal, different seed count => different fingerprint.
    let out = divebatch_cmd()
        .args(sweep_args(&["--seeds", "2", "--resume", journal.to_str().unwrap()]))
        .output()
        .expect("mismatched resume");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        !out.status.success(),
        "mismatched resume must be refused: {stderr}"
    );
    assert!(
        stderr.contains("fingerprint"),
        "refusal names the fingerprint: {stderr}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
