//! Shared helper for the integration/engine test suites.

use divebatch::runtime::Runtime;

/// The tiny-artifacts runtime (`make artifacts-tiny`), or `None` — with
/// a stderr note, so the calling test skips — when either the artifacts
/// or a real execution backend is unavailable (the vendored `xla` stub
/// compiles but cannot execute; see rust/vendor/xla).
pub fn runtime() -> Option<Runtime> {
    let rt = match Runtime::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: artifacts missing — run `make artifacts-tiny` ({e:#})");
            return None;
        }
    };
    if !rt.has_execution_backend() {
        eprintln!("skipping: xla stub backend cannot execute (see rust/vendor/xla)");
        return None;
    }
    Some(rt)
}
