//! Shared helpers for the integration/engine test suites.
//!
//! The numeric suites run **by default** against the committed fixtures
//! (rust/tests/fixtures/artifacts: the synthetic-convex `tinylogreg8`
//! model) through the pure-Rust interpreter backend, so `cargo test`
//! executes every test on every machine — no AOT build, no native XLA,
//! zero skips.
//!
//! A real backend is the opt-in path: set `DIVEBATCH_TEST_ARTIFACTS` to a
//! `make artifacts-tiny` output directory (with the `xla` dependency
//! pointed at the real binding in rust/Cargo.toml) and the
//! [`real_runtime`]-gated tests run too.

#![allow(dead_code)] // each test target links only the helpers it uses

use divebatch::runtime::Runtime;

/// Committed fixture artifacts for the interpreter backend.
pub fn fixtures_dir() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/artifacts")
}

/// The default test runtime: committed fixtures + interpreter backend.
/// Available everywhere, so this never skips; it panics loudly on the
/// only misconfiguration that can break it (forcing the stub backend).
pub fn runtime() -> Runtime {
    let rt = Runtime::load(fixtures_dir())
        .expect("committed fixtures missing — regenerate with `python -m compile.fixtures`");
    assert!(
        rt.has_execution_backend(),
        "DIVEBATCH_BACKEND=stub forces the compile-only backend; unset it to run \
         the numeric test suite on the interpreter"
    );
    rt
}

/// Opt-in real-backend runtime: `DIVEBATCH_TEST_ARTIFACTS=<dir>` names an
/// AOT artifact tree (e.g. `make artifacts-tiny` output).  Returns `None`
/// when the opt-in is absent — callers are extra coverage on top of the
/// always-on fixture suite, not gates for it.
///
/// The opt-in also requires a REAL backend linked (the `real_backend_*`
/// tests use ops like convolution that the interp backend rejects); with
/// the vendored crate still in Cargo.toml the env var is noted and
/// ignored instead of hard-failing mid-test.
pub fn real_runtime() -> Option<Runtime> {
    let dir = std::env::var("DIVEBATCH_TEST_ARTIFACTS").ok()?;
    let rt = Runtime::load(&dir)
        .unwrap_or_else(|e| panic!("DIVEBATCH_TEST_ARTIFACTS={dir}: cannot load ({e:#})"));
    let platform = rt.platform();
    if platform == "interp" || platform == "stub" {
        eprintln!(
            "real-backend opt-in inert: DIVEBATCH_TEST_ARTIFACTS is set but the \
             vendored xla crate ({platform}) is linked — point rust/Cargo.toml \
             at the real xla_extension binding to run the real_backend_* tests"
        );
        return None;
    }
    Some(rt)
}
