//! End-to-end trainer integration over the committed interpreter
//! fixtures: full epoch loops through the runtime, policies adapting
//! batch sizes, loss decreasing on learnable data, determinism, and the
//! device-update path.  Runs everywhere in plain `cargo test` — no AOT
//! artifacts, no native XLA, no skips.
//!
//! The conv-resnet image run additionally executes on a real backend
//! when `DIVEBATCH_TEST_ARTIFACTS` opts in (the interpreter fixtures
//! ship only the convex model).

mod common;

use common::{real_runtime, runtime};
use divebatch::cluster::ClusterModel;
use divebatch::coordinator::{LrSchedule, Policy, TrainConfig, Trainer};
use divebatch::data::{synthetic, SyntheticSpec};

fn synth_split(n: usize, seed: u64) -> (divebatch::Dataset, divebatch::Dataset) {
    synthetic::generate(&SyntheticSpec {
        n,
        d: 8,
        noise: 0.05,
        seed,
    })
    .split(0.8)
}

fn cluster() -> ClusterModel {
    ClusterModel::a100x4(9, 1e3)
}

fn base_cfg(policy: Policy, epochs: usize) -> TrainConfig {
    TrainConfig::new(
        "tinylogreg8",
        policy,
        LrSchedule::constant(0.5, true),
        epochs,
    )
}

/// Run one config over the fixture runtime.
fn run(cfg: TrainConfig, n: usize, data_seed: u64) -> divebatch::RunRecord {
    let rt = runtime();
    let (train, val) = synth_split(n, data_seed);
    Trainer::new(&rt, cfg, train, val, cluster())
        .unwrap()
        .run()
        .unwrap()
        .record
}

#[test]
fn sgd_learns_separable_data() {
    let rec = run(base_cfg(Policy::Fixed { m: 8 }, 15), 400, 1);
    assert_eq!(rec.epochs.len(), 15);
    let first = &rec.epochs[0];
    let last = rec.epochs.last().unwrap();
    assert!(
        last.val_loss < 0.7 * first.val_loss,
        "val loss {} -> {}",
        first.val_loss,
        last.val_loss
    );
    assert!(last.val_acc > 85.0, "val acc {}", last.val_acc);
    // Steps per epoch = ceil(320/8).
    assert_eq!(first.steps, 40);
    assert_eq!(first.batch_size, 8);
}

#[test]
fn divebatch_adapts_batch_size_and_records_diversity() {
    let policy = Policy::DiveBatch {
        m0: 4,
        delta: 0.5,
        m_max: 8,
    };
    let rec = run(base_cfg(policy, 10), 200, 2);
    // Diversity recorded every epoch.
    assert!(rec.epochs.iter().all(|e| e.delta_hat.is_some()));
    assert!(rec.epochs.iter().all(|e| e.n_delta.unwrap() > 0.0));
    // Batch stays within [m0, m_max].
    assert!(rec
        .epochs
        .iter()
        .all(|e| (4..=8).contains(&e.batch_size)));
    // With delta=0.5 and n=160, target = 80 * delta_hat >> 8 -> should
    // reach m_max quickly (diversity >= 1/n always).
    assert_eq!(rec.end_batch_size(), 8);
}

#[test]
fn oracle_records_exact_diversity() {
    let policy = Policy::Oracle {
        m0: 4,
        delta: 0.5,
        m_max: 8,
    };
    let rec = run(base_cfg(policy, 6), 200, 3);
    assert!(rec.epochs.iter().all(|e| e.exact_delta.is_some()));
    assert!(rec.epochs.iter().all(|e| e.delta_hat.is_none()));
    let d = rec.epochs[0].exact_delta.unwrap();
    assert!(d.is_finite() && d > 0.0);
}

#[test]
fn oracle_and_divebatch_deltas_agree_roughly_on_logreg() {
    // For a near-convex problem with a small lr, the within-epoch
    // parameter drift is small, so Delta_hat ~ exact Delta (Figure 2 top).
    let mut dive_cfg = base_cfg(
        Policy::DiveBatch {
            m0: 4,
            delta: 0.001,
            m_max: 8,
        },
        5,
    );
    dive_cfg.schedule = LrSchedule::constant(0.05, false);
    let dive = run(dive_cfg, 200, 4);
    let mut oracle_cfg = base_cfg(
        Policy::Oracle {
            m0: 4,
            delta: 0.001,
            m_max: 8,
        },
        5,
    );
    oracle_cfg.schedule = LrSchedule::constant(0.05, false);
    let oracle = run(oracle_cfg, 200, 4);
    for (d, o) in dive.epochs.iter().zip(&oracle.epochs) {
        let dh = d.delta_hat.unwrap();
        let ex = o.exact_delta.unwrap();
        let ratio = dh / ex;
        assert!(
            (0.2..5.0).contains(&ratio),
            "epoch {}: delta_hat {dh} vs exact {ex}",
            d.epoch
        );
    }
}

#[test]
fn runs_are_deterministic_per_seed() {
    let a = run(base_cfg(Policy::Fixed { m: 8 }, 5), 200, 7);
    let b = run(base_cfg(Policy::Fixed { m: 8 }, 5), 200, 7);
    for (x, y) in a.epochs.iter().zip(&b.epochs) {
        assert_eq!(x.val_loss, y.val_loss);
        assert_eq!(x.train_loss, y.train_loss);
        assert_eq!(x.batch_size, y.batch_size);
    }
}

#[test]
fn device_update_matches_rust_update() {
    let mk = |device: bool| {
        let mut cfg = base_cfg(Policy::Fixed { m: 8 }, 5);
        cfg.device_update = device;
        run(cfg, 200, 9)
    };
    let (host, dev) = (mk(false), mk(true));
    for (h, d) in host.epochs.iter().zip(&dev.epochs) {
        assert!(
            (h.val_loss - d.val_loss).abs() < 1e-4,
            "epoch {}: {} vs {}",
            h.epoch,
            h.val_loss,
            d.val_loss
        );
    }
}

#[test]
fn momentum_and_weight_decay_run() {
    let mut cfg = base_cfg(Policy::Fixed { m: 8 }, 8);
    cfg.momentum = 0.9;
    cfg.weight_decay = 1e-4;
    cfg.schedule = LrSchedule::constant(0.1, false);
    let rec = run(cfg, 300, 11);
    let last = rec.epochs.last().unwrap();
    assert!(last.val_loss.is_finite());
    assert!(last.val_acc > 70.0, "{}", last.val_acc);
}

#[test]
fn lr_schedule_decays_in_records() {
    let mut cfg = base_cfg(Policy::Fixed { m: 8 }, 6);
    cfg.schedule = LrSchedule {
        base: 1.0,
        decay: 0.5,
        every: 2,
        rescale_with_batch: false,
    };
    let rec = run(cfg, 100, 12);
    let lrs: Vec<f64> = rec.epochs.iter().map(|e| e.lr).collect();
    assert_eq!(lrs, vec![1.0, 1.0, 0.5, 0.5, 0.25, 0.25]);
}

#[test]
fn goyal_rescaling_scales_lr_with_batch() {
    let policy = Policy::DiveBatch {
        m0: 4,
        delta: 1.0,
        m_max: 8,
    };
    let mut cfg = base_cfg(policy, 6);
    cfg.schedule = LrSchedule::constant(0.2, true);
    let rec = run(cfg, 200, 13);
    for e in &rec.epochs {
        let want = 0.2 * e.batch_size as f64 / 4.0;
        assert!((e.lr - want).abs() < 1e-12, "epoch {}: {}", e.epoch, e.lr);
    }
}

#[test]
fn simulated_time_accumulates_monotonically() {
    let rec = run(base_cfg(Policy::Fixed { m: 8 }, 4), 100, 14);
    let mut prev = 0.0;
    for e in &rec.epochs {
        assert!(e.cum_sim_s > prev);
        assert!(e.cum_wall_s >= e.wall_s);
        prev = e.cum_sim_s;
    }
}

#[test]
fn adam_trains_logreg() {
    // Paper §6 extension: DiveBatch + Adam.  Adam needs a much smaller lr.
    let mut cfg = base_cfg(
        Policy::DiveBatch {
            m0: 4,
            delta: 0.5,
            m_max: 8,
        },
        12,
    );
    cfg.use_adam = true;
    cfg.schedule = divebatch::coordinator::LrSchedule::constant(0.05, false);
    let rec = run(cfg, 300, 21);
    let first = &rec.epochs[0];
    let last = rec.epochs.last().unwrap();
    assert!(last.val_loss < first.val_loss);
    assert!(last.val_acc > 80.0, "val acc {}", last.val_acc);
    // Diversity still flows to the policy under Adam.
    assert!(rec.epochs.iter().all(|e| e.delta_hat.is_some()));
}

#[test]
fn adam_with_device_update_rejected() {
    let rt = runtime();
    let (train, val) = synth_split(100, 22);
    let mut cfg = base_cfg(Policy::Fixed { m: 8 }, 1);
    cfg.use_adam = true;
    cfg.device_update = true;
    let trainer = Trainer::new(&rt, cfg, train, val, cluster()).unwrap();
    assert!(trainer.run().is_err());
}

#[test]
fn sgld_boosts_diversity_and_batch_growth() {
    // Same config with and without SGLD noise: the noised run must report
    // higher Delta_hat (Yin et al.'s mechanism) and thus reach larger
    // batches at least as fast.
    let mk = |sigma: f64| {
        let mut cfg = base_cfg(
            Policy::DiveBatch {
                m0: 4,
                delta: 0.02,
                m_max: 8,
            },
            6,
        );
        cfg.schedule = divebatch::coordinator::LrSchedule::constant(0.05, false);
        cfg.sgld = divebatch::coordinator::SgldConfig { sigma };
        run(cfg, 200, 23)
    };
    let (plain, noised) = (mk(0.0), mk(0.5));
    for (p, n) in plain.epochs.iter().zip(&noised.epochs) {
        let (dp, dn) = (p.delta_hat.unwrap(), n.delta_hat.unwrap());
        assert!(
            dn > dp,
            "epoch {}: sgld delta {dn} should exceed plain {dp}",
            p.epoch
        );
    }
    assert!(noised.end_batch_size() >= plain.end_batch_size());
    // And training still works under the injected noise.
    assert!(noised.epochs.last().unwrap().val_acc > 70.0);
}

#[test]
fn mismatched_dataset_rejected() {
    let rt = runtime();
    // Image dataset against logreg model must fail fast.
    let img = divebatch::data::images::generate(&divebatch::ImageSpec {
        num_classes: 4,
        per_class: 4,
        size: 8,
        noise: 0.3,
        max_shift: 1,
        seed: 0,
    });
    let (train, val) = img.split(0.8);
    let cfg = base_cfg(Policy::Fixed { m: 4 }, 1);
    assert!(Trainer::new(&rt, cfg, train, val, cluster()).is_err());
}

#[test]
fn real_backend_tiny_resnet_trains_on_images() {
    let Some(rt) = real_runtime() else {
        return; // opt-in extra (needs conv support, i.e. a real backend)
    };
    let img = divebatch::data::images::generate(&divebatch::ImageSpec {
        num_classes: 4,
        per_class: 30,
        size: 8,
        noise: 0.4,
        max_shift: 1,
        seed: 5,
    });
    let (train, val) = img.split(0.8);
    let mut cfg = TrainConfig::new(
        "tinyresnet4",
        Policy::DiveBatch {
            m0: 4,
            delta: 0.5,
            m_max: 8,
        },
        LrSchedule::constant(0.05, true),
        8,
    );
    cfg.momentum = 0.9;
    let out = Trainer::new(&rt, cfg, train, val, ClusterModel::a100x4(428, 1e5))
        .unwrap()
        .run()
        .unwrap();
    let rec = out.record;
    let first = &rec.epochs[0];
    let last = rec.epochs.last().unwrap();
    assert!(last.train_loss < first.train_loss, "{rec:?}");
    // 4 classes, learnable templates: must beat chance (25%).
    assert!(last.val_acc > 30.0, "val acc {}", last.val_acc);
}
