//! Integration suite for `divebatch serve`: serving equivalence,
//! concurrent load with observable batch adaptation, strict request
//! validation, cache bounds, sweep streaming, graceful shutdown.
//!
//! Everything runs in-process against [`divebatch::Server::spawn`] on
//! the committed fixtures — no network assumptions beyond loopback, no
//! external process (CI's load smoke covers the spawned-binary path).

mod common;

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;

use divebatch::config::{flops_per_sample, DatasetSpec};
use divebatch::coordinator::{LrSchedule, PolicyRegistry, TrainConfig};
use divebatch::data::SyntheticSpec;
use divebatch::engine::TrialSpec;
use divebatch::util::json::{self, Json};
use divebatch::{ClusterSpec, ServeConfig, Server};

// ------------------------------------------------------------ helpers

/// One-shot HTTP client returning the raw response (head + body).
fn request_raw(addr: SocketAddr, method: &str, path: &str, body: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n",
        body.len()
    );
    s.write_all(head.as_bytes()).expect("write head");
    s.write_all(body.as_bytes()).expect("write body");
    s.flush().expect("flush");
    let mut raw = String::new();
    s.read_to_string(&mut raw).expect("read response");
    raw
}

/// One-shot HTTP client: send a request, read to EOF (the server is
/// `Connection: close`), return (status, body).
fn request(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let raw = request_raw(addr, method, path, body);
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|t| t.parse().ok())
        .unwrap_or_else(|| panic!("malformed response: {raw:?}"));
    let body = raw
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    (status, body)
}

fn post_trial(addr: SocketAddr, body: &str) -> (u16, String) {
    request(addr, "POST", "/trial", body)
}

fn get_stats(addr: SocketAddr) -> Json {
    let (status, body) = request(addr, "GET", "/stats", "");
    assert_eq!(status, 200, "stats must serve: {body}");
    json::parse(&body).expect("stats is valid JSON")
}

fn stat(j: &Json, section: &str, key: &str) -> f64 {
    j.get(section)
        .and_then(|s| s.get(key))
        .and_then(|v| v.as_f64())
        .unwrap_or_else(|| panic!("missing /stats field {section}.{key}"))
}

/// The error envelope of a rejection body.
fn error_of(body: &str) -> Json {
    json::parse(body.trim())
        .unwrap_or_else(|e| panic!("error body must be JSON ({e}): {body:?}"))
        .get("error")
        .cloned()
        .expect("error envelope")
}

fn serve_cfg() -> ServeConfig {
    ServeConfig::new("127.0.0.1:0", common::fixtures_dir())
}

/// The request body all equivalence tests use, parameterized by seed.
/// tinylogreg8 is the committed d=8 fixture model; the dataset matches.
fn trial_body(seed: usize, epochs: usize) -> String {
    format!(
        r#"{{"model":"tinylogreg8","policy":"sgd:m=4","seed":{seed},"epochs":{epochs},
            "dataset":{{"kind":"synthetic","n":40,"d":8,"noise":0.1,"seed":1000}}}}"#
    )
}

/// The offline twin of [`trial_body`]: same spec through the engine
/// directly, no server involved.
fn offline_spec(seed: u64, epochs: usize) -> TrialSpec {
    let policy = PolicyRegistry::builtin().parse("sgd:m=4").expect("policy");
    let schedule = LrSchedule {
        base: 0.1,
        decay: 0.75,
        every: 20,
        rescale_with_batch: false,
    };
    let mut cfg = TrainConfig::new("tinylogreg8", policy, schedule, epochs);
    cfg.cluster = ClusterSpec {
        workers: 4,
        div_overhead: 0.9,
        ..ClusterSpec::default()
    };
    cfg.verbose = false;
    TrialSpec {
        cfg,
        dataset: DatasetSpec::Synthetic(SyntheticSpec {
            n: 40,
            d: 8,
            noise: 0.1,
            seed: 1000,
        }),
        flops_per_sample: flops_per_sample("tinylogreg8"),
        trial: seed,
    }
}

fn offline_canonical(seed: u64, epochs: usize) -> String {
    let rt = common::runtime();
    let rec = offline_spec(seed, epochs).execute(&rt).expect("offline trial");
    rec.to_canonical_json().to_string()
}

// -------------------------------------------------------------- tests

/// Satellite 3 (single-client half): a trial served over HTTP is
/// byte-identical to the offline engine's canonical record.
#[test]
fn served_trial_matches_offline_canonical_record() {
    let handle = Server::spawn(serve_cfg()).expect("spawn");
    let (status, body) = post_trial(handle.addr(), &trial_body(0, 2));
    assert_eq!(status, 200, "trial must succeed: {body}");
    assert_eq!(body.trim_end(), offline_canonical(0, 2), "served != offline");
    handle.stop().expect("graceful stop");
}

/// The acceptance-criteria load test: >= 64 concurrent clients against
/// a live server — every response is a valid canonical record, served
/// bytes still match offline bytes under load, and `/stats` shows the
/// admission batch size actually adapted to queue depth.
#[test]
fn concurrent_load_valid_adapting_and_equivalent() {
    let mut cfg = serve_cfg();
    cfg.max_clients = 128;
    cfg.max_queue = 512;
    cfg.jobs = 2;
    let handle = Server::spawn(cfg).expect("spawn");
    let addr = handle.addr();

    const CLIENTS: usize = 64;
    let responses: Vec<(usize, u16, String)> = std::thread::scope(|s| {
        let mut joins = Vec::new();
        for i in 0..CLIENTS {
            joins.push(s.spawn(move || {
                let (status, body) = post_trial(addr, &trial_body(i % 8, 1));
                (i, status, body)
            }));
        }
        joins.into_iter().map(|j| j.join().expect("client")).collect()
    });

    // Every response is a 200 carrying one parseable record line, and
    // all clients that asked for the same seed got identical bytes.
    let mut by_seed: Vec<Option<String>> = vec![None; 8];
    for (i, status, body) in &responses {
        assert_eq!(*status, 200, "client {i} failed: {body}");
        let line = body.trim_end();
        let rec = json::parse(line).expect("record line parses");
        assert!(rec.get("epochs").is_some(), "client {i}: not a record: {line}");
        match &by_seed[i % 8] {
            None => by_seed[i % 8] = Some(line.to_string()),
            Some(prev) => assert_eq!(prev, line, "same seed, different bytes"),
        }
    }
    // ...and under-load bytes equal offline bytes.
    assert_eq!(
        by_seed[0].as_deref().expect("seed 0 served"),
        offline_canonical(0, 1),
        "served-under-load != offline"
    );

    let stats = get_stats(addr);
    assert!(stat(&stats, "admission", "submitted") >= CLIENTS as f64);
    assert_eq!(stat(&stats, "admission", "trials_failed"), 0.0);
    assert!(
        stat(&stats, "admission", "batch_size_max_seen") >= 2.0,
        "64 concurrent clients must force the admission batch above 1: {stats:?}",
    );
    assert!(stat(&stats, "admission", "adapt_events") >= 1.0);
    assert!(stat(&stats, "admission", "batches_dispatched") >= 1.0);
    // The exec cache saw real traffic and reports it.
    assert!(stat(&stats, "exec_cache", "hits") >= 1.0);
    assert!(stat(&stats, "exec_cache", "entries") >= 1.0);
    handle.stop().expect("graceful stop");
}

/// Satellite 1: the strict-validation error matrix — every rejection is
/// a structured 400-class answer naming the field, never a 500.
#[test]
fn validation_rejections_are_typed() {
    let handle = Server::spawn(serve_cfg()).expect("spawn");
    let addr = handle.addr();

    // Unknown field, with a did-you-mean from the registry machinery.
    let (status, body) =
        post_trial(addr, r#"{"model":"tinylogreg8","policy":"sgd:m=4","epochz":3}"#);
    assert_eq!(status, 400);
    let e = error_of(&body);
    assert_eq!(e.get("code").unwrap().as_str(), Some("unknown_field"));
    assert_eq!(e.get("field").unwrap().as_str(), Some("epochz"));
    assert_eq!(e.get("did_you_mean").unwrap().as_str(), Some("epochs"));

    // Malformed policy spec: the registry's own did-you-mean flows through.
    let (status, body) = post_trial(addr, r#"{"model":"tinylogreg8","policy":"sdg:m=4"}"#);
    assert_eq!(status, 400);
    let e = error_of(&body);
    assert_eq!(e.get("code").unwrap().as_str(), Some("bad_policy"));
    let msg = e.get("message").unwrap().as_str().unwrap().to_string();
    assert!(msg.contains("sgd"), "policy error should suggest sgd: {msg}");

    // Unknown model, suggesting the fixture model.
    let (status, body) = post_trial(addr, r#"{"model":"tinylogreg","policy":"sgd:m=4"}"#);
    assert_eq!(status, 400);
    let e = error_of(&body);
    assert_eq!(e.get("code").unwrap().as_str(), Some("unknown_model"));
    assert_eq!(e.get("did_you_mean").unwrap().as_str(), Some("tinylogreg8"));

    // Out-of-range value names the field.
    let (status, body) =
        post_trial(addr, r#"{"model":"tinylogreg8","policy":"sgd:m=4","epochs":0}"#);
    assert_eq!(status, 400);
    let e = error_of(&body);
    assert_eq!(e.get("code").unwrap().as_str(), Some("out_of_range"));
    assert_eq!(e.get("field").unwrap().as_str(), Some("epochs"));

    // Wrong type.
    let (status, body) =
        post_trial(addr, r#"{"model":"tinylogreg8","policy":"sgd:m=4","epochs":"many"}"#);
    assert_eq!(status, 400);
    assert_eq!(error_of(&body).get("code").unwrap().as_str(), Some("bad_type"));

    // Missing required field.
    let (status, body) = post_trial(addr, r#"{"model":"tinylogreg8"}"#);
    assert_eq!(status, 400);
    let e = error_of(&body);
    assert_eq!(e.get("code").unwrap().as_str(), Some("missing_field"));
    assert_eq!(e.get("field").unwrap().as_str(), Some("policy"));

    // Malformed JSON, non-object JSON, and pathological nesting.
    let deep = "[".repeat(4000) + &"]".repeat(4000);
    for bad in ["{not json", "[1,2]", deep.as_str()] {
        let (status, body) = post_trial(addr, bad);
        assert_eq!(status, 400, "body {:?} must 400: {body}", &bad[..bad.len().min(20)]);
        let code = error_of(&body).get("code").unwrap().as_str().unwrap().to_string();
        assert!(code == "bad_json" || code == "bad_type", "typed code, got {code}");
    }

    // Routing errors are typed too.
    let (status, body) = request(addr, "GET", "/nope", "");
    assert_eq!(status, 404);
    assert_eq!(error_of(&body).get("code").unwrap().as_str(), Some("not_found"));
    let (status, body) = request(addr, "GET", "/trial", "");
    assert_eq!(status, 405);
    assert_eq!(
        error_of(&body).get("code").unwrap().as_str(),
        Some("method_not_allowed")
    );

    handle.stop().expect("graceful stop");
}

/// Tentpole: both shared caches respect their bounds under serve
/// traffic — entry counts stay at/below the caps, evictions are
/// observed, and the results cache demonstrably answers repeats.
#[test]
fn shared_caches_stay_bounded_and_memoize() {
    let results_dir: PathBuf = std::env::temp_dir().join(format!(
        "divebatch-serve-cache-test-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&results_dir);

    let mut cfg = serve_cfg();
    // tinylogreg8's warmup surface alone is ~6 executables, so a cap of
    // 2 forces evictions on the very first trial.
    cfg.exec_cache_entries = 2;
    cfg.results_dir = Some(results_dir.to_string_lossy().into_owned());
    cfg.results_max_entries = 2;
    let handle = Server::spawn(cfg).expect("spawn");
    let addr = handle.addr();

    // Three distinct specs -> three results-cache stores under a cap of 2.
    for seed in 0..3 {
        let (status, body) = post_trial(addr, &trial_body(seed, 1));
        assert_eq!(status, 200, "seed {seed}: {body}");
    }
    // A repeat of the last spec must come back from the results cache,
    // byte-identical to the first serving.
    let (_, first) = post_trial(addr, &trial_body(2, 1));
    let (status, again) = post_trial(addr, &trial_body(2, 1));
    assert_eq!(status, 200);
    assert_eq!(first, again, "cache hit must serve identical bytes");

    let stats = get_stats(addr);
    assert!(
        stat(&stats, "exec_cache", "entries") <= 2.0,
        "exec cache over cap: {stats:?}"
    );
    assert!(stat(&stats, "exec_cache", "evictions") >= 1.0);
    assert!(stat(&stats, "results_cache", "entries") <= 2.0);
    assert!(stat(&stats, "results_cache", "evictions") >= 1.0);
    assert!(stat(&stats, "results_cache", "stores") >= 3.0);
    assert!(stat(&stats, "results_cache", "hits") >= 1.0);
    assert!(stat(&stats, "admission", "results_hits") >= 1.0);

    handle.stop().expect("graceful stop");
    let _ = std::fs::remove_dir_all(&results_dir);
}

/// Satellite 3 (sweep half): a sweep streams one canonical line per
/// trial in policy-major, seed-minor order — the offline expansion
/// order — and each line equals its offline twin.
#[test]
fn sweep_streams_offline_identical_lines_in_order() {
    let handle = Server::spawn(serve_cfg()).expect("spawn");
    let body = r#"{"model":"tinylogreg8","policies":["sgd:m=4","sgd:m=8"],"seeds":2,
                   "epochs":1,"dataset":{"kind":"synthetic","n":40,"d":8,"noise":0.1,"seed":1000}}"#;
    let (status, out) = request(handle.addr(), "POST", "/sweep", body);
    assert_eq!(status, 200, "sweep failed: {out}");
    let lines: Vec<&str> = out.trim_end().lines().collect();
    assert_eq!(lines.len(), 4, "2 policies x 2 seeds = 4 lines: {out}");

    let rt = common::runtime();
    let mut expected = Vec::new();
    for policy in ["sgd:m=4", "sgd:m=8"] {
        for seed in 0..2u64 {
            let mut spec = offline_spec(seed, 1);
            spec.cfg.policy = PolicyRegistry::builtin().parse(policy).expect("policy");
            expected.push(spec.execute(&rt).expect("offline").to_canonical_json().to_string());
        }
    }
    assert_eq!(lines, expected, "sweep stream != offline expansion");
    handle.stop().expect("graceful stop");
}

/// Every backpressure 503 must carry a `Retry-After` header so clients
/// can pace their retries.  The connection-cap path is driven here by
/// pinning `max_clients = 1` with an idle connection holding the slot
/// (the handler blocks reading its request); queue-full and draining
/// share the same `respond_error` rendering.
#[test]
fn backpressure_503_carries_retry_after() {
    let mut cfg = serve_cfg();
    cfg.max_clients = 1;
    let handle = Server::spawn(cfg).expect("spawn");
    let addr = handle.addr();

    // Occupy the single permit with a connection that never sends its
    // request; the handler thread blocks in read_request.
    let idle = TcpStream::connect(addr).expect("idle connect");
    // Give the accept loop time to take the permit for `idle` before
    // the probe arrives (accepts are processed in order).
    std::thread::sleep(std::time::Duration::from_millis(200));

    let raw = request_raw(addr, "GET", "/healthz", "");
    let status: u16 = raw.split_whitespace().nth(1).and_then(|t| t.parse().ok()).unwrap_or(0);
    assert_eq!(status, 503, "second connection must be capped: {raw:?}");
    let head = raw.split("\r\n\r\n").next().unwrap_or("");
    assert!(
        head.lines().any(|l| l.to_ascii_lowercase().starts_with("retry-after:")),
        "503 must carry Retry-After: {head:?}"
    );
    let err = error_of(raw.split_once("\r\n\r\n").map(|(_, b)| b).unwrap_or(""));
    assert_eq!(err.get("code").and_then(|c| c.as_str()), Some("too_many_clients"));

    drop(idle);
    handle.stop().expect("graceful stop");
}

/// Satellite 5's in-process half: a stopping server drains (the stop
/// call returns cleanly) and then refuses new connections.
#[test]
fn graceful_stop_drains_then_refuses() {
    let handle = Server::spawn(serve_cfg()).expect("spawn");
    let addr = handle.addr();
    let (status, _) = request(addr, "GET", "/healthz", "");
    assert_eq!(status, 200);
    handle.stop().expect("graceful stop");
    assert!(
        TcpStream::connect(addr).is_err(),
        "stopped server must refuse connections"
    );
}
