//! Policy-level integration: batch-size trajectories through real training
//! (AdaBatch schedule shape, DiveBatch growth, plan execution over mixed
//! ladder rungs), registry-parsed specs vs enum-built configs, wrapper
//! and step-level policies through the real trainer, and the
//! RunSpec/preset machinery end to end.
//!
//! Runs everywhere over the committed interpreter fixtures
//! (rust/tests/fixtures) — no AOT artifacts, no native XLA, no skips.

mod common;

use common::runtime;
use divebatch::config::presets::{preset, Scale};
use divebatch::config::{DatasetSpec, RunSpec};
use divebatch::coordinator::{LrSchedule, Policy, PolicyRegistry, TrainConfig};
use divebatch::data::SyntheticSpec;
use divebatch::{AdaptContext, BatchPolicy, Decision, DiversityNeed, PolicyError, PolicyHandle};

fn tiny_synth(n: usize) -> DatasetSpec {
    DatasetSpec::Synthetic(SyntheticSpec {
        n,
        d: 8,
        noise: 0.05,
        seed: 77,
    })
}

fn run_policy(policy: Policy, epochs: usize, n: usize) -> divebatch::RunRecord {
    let rt = runtime();
    let spec = RunSpec {
        cfg: TrainConfig::new("tinylogreg8", policy, LrSchedule::constant(0.3, false), epochs),
        dataset: tiny_synth(n),
        trials: 1,
        flops_per_sample: 1e3,
    };
    spec.run(&rt).unwrap().into_iter().next().unwrap()
}

#[test]
fn adabatch_trajectory_through_real_training() {
    let rec = run_policy(
        Policy::AdaBatch {
            m0: 4,
            factor: 2,
            every: 3,
            m_max: 8,
        },
        9,
        100,
    );
    let sizes: Vec<usize> = rec.epochs.iter().map(|e| e.batch_size).collect();
    assert_eq!(sizes, vec![4, 4, 4, 8, 8, 8, 8, 8, 8]);
    // AdaBatch never requests diversity instrumentation.
    assert!(rec.epochs.iter().all(|e| e.delta_hat.is_none()));
}

#[test]
fn divebatch_growth_is_bounded_and_instrumented() {
    let rec = run_policy(
        Policy::DiveBatch {
            m0: 4,
            delta: 1.0,
            m_max: 8,
        },
        6,
        120,
    );
    assert!(rec.epochs[0].batch_size == 4);
    assert!(rec.epochs.iter().all(|e| e.batch_size <= 8));
    assert!(rec.epochs.iter().all(|e| e.delta_hat.is_some()));
}

#[test]
fn mixed_ladder_plan_executes_odd_batches() {
    // n=90, m=7 exercises tail batches (90 = 12*7 + 6) and padded blocks
    // over a {4, 8} ladder every epoch.
    let rec = run_policy(Policy::Fixed { m: 7 }, 3, 112);
    // 80% of 112 = 90 train rows; ceil(90/7) steps.
    let steps = rec.epochs[0].steps;
    assert_eq!(steps, 90usize.div_ceil(7));
    assert!(rec.epochs.iter().all(|e| e.val_loss.is_finite()));
}

#[test]
fn runspec_multi_trial_aggregation() {
    let rt = runtime();
    let spec = RunSpec {
        cfg: TrainConfig::new(
            "tinylogreg8",
            Policy::Fixed { m: 8 },
            LrSchedule::constant(0.3, false),
            4,
        ),
        dataset: tiny_synth(100),
        trials: 3,
        flops_per_sample: 1e3,
    };
    let records = spec.run(&rt).unwrap();
    assert_eq!(records.len(), 3);
    // Trials differ (different data draws + init seeds).
    assert_ne!(
        records[0].final_val_acc(),
        records[1].final_val_acc()
    );
    // But all are labelled the same arm.
    assert!(records.iter().all(|r| r.label == "SGD (8)"));
}

#[test]
fn csv_writes_from_real_run() {
    let rec = run_policy(Policy::Fixed { m: 8 }, 3, 80);
    let dir = std::env::temp_dir().join("divebatch-test-csv");
    let path = dir.join("run.csv");
    rec.write_csv(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.starts_with("epoch,batch_size"));
    assert_eq!(text.lines().count(), 4);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn registry_spec_matches_enum_trajectory() {
    // Acceptance gate for the BatchPolicy redesign: a registry-parsed
    // spec must produce a byte-identical run to the legacy enum config.
    let by_enum = run_policy(
        Policy::DiveBatch {
            m0: 4,
            delta: 1.0,
            m_max: 8,
        },
        6,
        120,
    );
    let rt = runtime();
    let handle = PolicyRegistry::builtin()
        .parse("divebatch:m0=4,delta=1,mmax=8")
        .unwrap();
    let spec = RunSpec {
        cfg: TrainConfig::new("tinylogreg8", handle, LrSchedule::constant(0.3, false), 6),
        dataset: tiny_synth(120),
        trials: 1,
        flops_per_sample: 1e3,
    };
    let by_spec = spec.run(&rt).unwrap().into_iter().next().unwrap();
    assert_eq!(by_enum.label, by_spec.label);
    assert_eq!(by_enum.policy_kind, by_spec.policy_kind);
    for (a, b) in by_enum.epochs.iter().zip(&by_spec.epochs) {
        assert_eq!(a.batch_size, b.batch_size, "epoch {}", a.epoch);
        assert_eq!(a.train_loss, b.train_loss, "epoch {}", a.epoch);
        assert_eq!(a.val_loss, b.val_loss, "epoch {}", a.epoch);
        assert_eq!(a.lr, b.lr, "epoch {}", a.epoch);
    }
}

#[test]
fn warmup_wrapper_through_real_training() {
    let rt = runtime();
    let handle = PolicyRegistry::builtin()
        .parse("warmup:epochs=3,m=2/sgd:m=8")
        .unwrap();
    let spec = RunSpec {
        cfg: TrainConfig::new("tinylogreg8", handle, LrSchedule::constant(0.3, false), 6),
        dataset: tiny_synth(100),
        trials: 1,
        flops_per_sample: 1e3,
    };
    let rec = spec.run(&rt).unwrap().into_iter().next().unwrap();
    let sizes: Vec<usize> = rec.epochs.iter().map(|e| e.batch_size).collect();
    assert_eq!(sizes, vec![2, 2, 2, 8, 8, 8]);
    assert!(rec.epochs.iter().all(|e| e.val_loss.is_finite()));
}

/// A step-level policy: after `grow_at_step` optimizer steps each epoch,
/// multiply the batch size for the remainder of the epoch.  Exercises
/// `wants_step_decisions` + `on_step` through the real trainer.
#[derive(Clone, Copy, Debug)]
struct StepRamp {
    m0: usize,
    grow_at_step: usize,
    factor: usize,
}

impl BatchPolicy for StepRamp {
    fn kind(&self) -> &'static str {
        "stepramp"
    }
    fn label(&self) -> String {
        format!("StepRamp ({})", self.m0)
    }
    fn initial(&self) -> usize {
        self.m0
    }
    fn wants_step_decisions(&self) -> bool {
        true
    }
    fn on_step(&mut self, ctx: &AdaptContext) -> Option<Decision> {
        (ctx.step == self.grow_at_step)
            .then(|| Decision::new(ctx.batch_size * self.factor, DiversityNeed::None))
    }
    fn on_epoch_end(&mut self, _ctx: &AdaptContext) -> Result<Decision, PolicyError> {
        // Restart every epoch from m0.
        Ok(Decision::new(self.m0, DiversityNeed::None))
    }
    fn render_spec(&self) -> String {
        format!("stepramp:m0={}", self.m0)
    }
    fn clone_box(&self) -> Box<dyn BatchPolicy> {
        Box::new(*self)
    }
}

#[test]
fn step_level_policy_resizes_mid_epoch() {
    let rt = runtime();
    let policy = PolicyHandle::new(Box::new(StepRamp {
        m0: 4,
        grow_at_step: 5,
        factor: 2,
    }));
    let spec = RunSpec {
        cfg: TrainConfig::new("tinylogreg8", policy, LrSchedule::constant(0.3, false), 2),
        dataset: tiny_synth(200), // 160 train rows
        trials: 1,
        flops_per_sample: 1e3,
    };
    let rec = spec.run(&rt).unwrap().into_iter().next().unwrap();
    // 5 steps at m=4 cover 20 rows; the remaining 140 run at m=8:
    // 5 + ceil(140/8) = 23 steps, vs 40 had the epoch stayed at m=4.
    assert_eq!(rec.epochs[0].steps, 5 + 140usize.div_ceil(8));
    // The boundary decision resets to m0, so every epoch repeats.
    assert_eq!(rec.epochs[1].steps, rec.epochs[0].steps);
    assert!(rec.epochs.iter().all(|e| e.val_loss.is_finite()));
}

#[test]
fn preset_machinery_smoke() {
    // Presets reference the full-size models; just validate resolution and
    // configuration here (the benches run them for real).
    for id in ["fig1-convex", "fig3-cifar10", "fig5-tin"] {
        let e = preset(id, Scale::quick()).unwrap();
        assert!(!e.runs.is_empty());
        for r in &e.runs {
            assert!(r.trials >= 1);
            assert!(r.cfg.epochs >= 1);
        }
    }
}

#[test]
fn profiler_sections_populated() {
    let rt = runtime();
    let spec = RunSpec {
        cfg: TrainConfig::new(
            "tinylogreg8",
            Policy::DiveBatch {
                m0: 4,
                delta: 0.5,
                m_max: 8,
            },
            LrSchedule::constant(0.3, false),
            2,
        ),
        dataset: tiny_synth(80),
        trials: 1,
        flops_per_sample: 1e3,
    };
    let (_, profile) = spec.run_trial(&rt, 0).unwrap();
    for section in ["gather", "execute", "update", "eval", "accumulate"] {
        assert!(
            profile.count(section) > 0,
            "missing profiler section {section}: {}",
            profile.report()
        );
    }
}
