//! Policy-level integration: batch-size trajectories through real training
//! (AdaBatch schedule shape, DiveBatch growth, plan execution over mixed
//! ladder rungs) and the RunSpec/preset machinery end to end.

use divebatch::config::presets::{preset, Scale};
use divebatch::config::{DatasetSpec, RunSpec};
use divebatch::coordinator::{LrSchedule, Policy, TrainConfig};
use divebatch::data::SyntheticSpec;
use divebatch::runtime::Runtime;

fn runtime() -> Runtime {
    Runtime::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
        .expect("artifacts missing — run `make artifacts-tiny` first")
}

fn tiny_synth(n: usize) -> DatasetSpec {
    DatasetSpec::Synthetic(SyntheticSpec {
        n,
        d: 8,
        noise: 0.05,
        seed: 77,
    })
}

fn run_policy(policy: Policy, epochs: usize, n: usize) -> divebatch::RunRecord {
    let rt = runtime();
    let spec = RunSpec {
        cfg: TrainConfig::new("tinylogreg8", policy, LrSchedule::constant(0.3, false), epochs),
        dataset: tiny_synth(n),
        trials: 1,
        flops_per_sample: 1e3,
    };
    spec.run(&rt).unwrap().into_iter().next().unwrap()
}

#[test]
fn adabatch_trajectory_through_real_training() {
    let rec = run_policy(
        Policy::AdaBatch {
            m0: 4,
            factor: 2,
            every: 3,
            m_max: 8,
        },
        9,
        100,
    );
    let sizes: Vec<usize> = rec.epochs.iter().map(|e| e.batch_size).collect();
    assert_eq!(sizes, vec![4, 4, 4, 8, 8, 8, 8, 8, 8]);
    // AdaBatch never requests diversity instrumentation.
    assert!(rec.epochs.iter().all(|e| e.delta_hat.is_none()));
}

#[test]
fn divebatch_growth_is_bounded_and_instrumented() {
    let rec = run_policy(
        Policy::DiveBatch {
            m0: 4,
            delta: 1.0,
            m_max: 8,
        },
        6,
        120,
    );
    assert!(rec.epochs[0].batch_size == 4);
    assert!(rec.epochs.iter().all(|e| e.batch_size <= 8));
    assert!(rec.epochs.iter().all(|e| e.delta_hat.is_some()));
}

#[test]
fn mixed_ladder_plan_executes_odd_batches() {
    // n=90, m=7 exercises tail batches (90 = 12*7 + 6) and padded blocks
    // over a {4, 8} ladder every epoch.
    let rec = run_policy(Policy::Fixed { m: 7 }, 3, 112);
    // ceil(89.6->89 train? n split 80% of 112 = 90 train) / 7 = 13 steps.
    let steps = rec.epochs[0].steps;
    assert_eq!(steps, 90usize.div_ceil(7));
    assert!(rec.epochs.iter().all(|e| e.val_loss.is_finite()));
}

#[test]
fn runspec_multi_trial_aggregation() {
    let rt = runtime();
    let spec = RunSpec {
        cfg: TrainConfig::new(
            "tinylogreg8",
            Policy::Fixed { m: 8 },
            LrSchedule::constant(0.3, false),
            4,
        ),
        dataset: tiny_synth(100),
        trials: 3,
        flops_per_sample: 1e3,
    };
    let records = spec.run(&rt).unwrap();
    assert_eq!(records.len(), 3);
    // Trials differ (different data draws + init seeds).
    assert_ne!(
        records[0].final_val_acc(),
        records[1].final_val_acc()
    );
    // But all are labelled the same arm.
    assert!(records.iter().all(|r| r.label == "SGD (8)"));
}

#[test]
fn csv_writes_from_real_run() {
    let rec = run_policy(Policy::Fixed { m: 8 }, 3, 80);
    let dir = std::env::temp_dir().join("divebatch-test-csv");
    let path = dir.join("run.csv");
    rec.write_csv(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.starts_with("epoch,batch_size"));
    assert_eq!(text.lines().count(), 4);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn preset_machinery_smoke() {
    // Presets reference the full-size models; just validate resolution and
    // configuration here (the benches run them for real).
    for id in ["fig1-convex", "fig3-cifar10", "fig5-tin"] {
        let e = preset(id, Scale::quick()).unwrap();
        assert!(!e.runs.is_empty());
        for r in &e.runs {
            assert!(r.trials >= 1);
            assert!(r.cfg.epochs >= 1);
        }
    }
}

#[test]
fn profiler_sections_populated() {
    let rt = runtime();
    let spec = RunSpec {
        cfg: TrainConfig::new(
            "tinylogreg8",
            Policy::DiveBatch {
                m0: 4,
                delta: 0.5,
                m_max: 8,
            },
            LrSchedule::constant(0.3, false),
            2,
        ),
        dataset: tiny_synth(80),
        trials: 1,
        flops_per_sample: 1e3,
    };
    let (_, profile) = spec.run_trial(&rt, 0).unwrap();
    for section in ["gather", "execute", "update", "eval", "accumulate"] {
        assert!(
            profile.count(section) > 0,
            "missing profiler section {section}: {}",
            profile.report()
        );
    }
}
