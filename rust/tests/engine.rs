//! Trial-engine + thread-safe-runtime acceptance tests.
//!
//! Three layers, all of which now run everywhere over the committed
//! interpreter fixtures (rust/tests/fixtures) — no skips:
//!
//! 1. **Static** — `Send + Sync` assertions (the compile-time guarantee
//!    that one `Runtime` may be shared across engine workers).
//! 2. **Compile cache** — concurrent compile-once semantics of the
//!    executable cache over real fixture entries (each parse-compiled by
//!    the interpreter backend exactly once).
//! 3. **Execution** — the serial-vs-parallel equivalence gate: a
//!    policies x seeds sweep produces byte-identical canonical records
//!    at `jobs = 1` and `jobs = 4`.

mod common;

use std::sync::Arc;

use divebatch::config::{DatasetSpec, RunSpec};
use divebatch::coordinator::{LrSchedule, Policy, TrainConfig};
use divebatch::data::SyntheticSpec;
use divebatch::engine::{TrialRunner, TrialSpec};
use divebatch::runtime::{Executable, Runtime};

// ------------------------------------------------------------ layer 1

/// Compile-enforced: these types cross (or are shared between) engine
/// worker threads.  If any stops being thread-safe, this test fails to
/// COMPILE rather than at runtime.
#[test]
fn runtime_layer_is_send_and_sync() {
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Runtime>();
    assert_send_sync::<Executable>();
    assert_send_sync::<Arc<Executable>>();
    assert_send_sync::<TrainConfig>();
    assert_send_sync::<divebatch::coordinator::PolicyHandle>();
    assert_send_sync::<RunSpec>();
    assert_send_sync::<TrialSpec>();
    assert_send_sync::<TrialRunner>();
    assert_send_sync::<divebatch::RunRecord>();
    assert_send_sync::<divebatch::engine::TrialError>();
}

// ------------------------------------------------------------ layer 2

#[test]
fn concurrent_first_access_compiles_exactly_once() {
    let rt = common::runtime();
    assert_eq!(rt.stats().compiles, 0);
    let rt = &rt;
    let handles: Vec<Arc<Executable>> = std::thread::scope(|s| {
        let workers: Vec<_> = (0..8)
            .map(|_| s.spawn(move || rt.train_exec("tinylogreg8", true, 4).unwrap()))
            .collect();
        workers.into_iter().map(|w| w.join().unwrap()).collect()
    });
    // Exactly one compile happened, and everyone shares the same object.
    assert_eq!(rt.stats().compiles, 1);
    assert_eq!(rt.cached_executables(), 1);
    for h in &handles[1..] {
        assert!(Arc::ptr_eq(&handles[0], h));
    }
    // Subsequent lookups hit the fast path.
    let again = rt.train_exec("tinylogreg8", true, 4).unwrap();
    assert!(Arc::ptr_eq(&handles[0], &again));
    assert_eq!(rt.stats().compiles, 1);
}

#[test]
fn distinct_entries_compile_concurrently_once_each() {
    let rt = common::runtime();
    let rt = &rt;
    std::thread::scope(|s| {
        // 3 distinct entries x 4 racing threads each.
        for _ in 0..4 {
            s.spawn(move || rt.train_exec("tinylogreg8", true, 4).unwrap());
            s.spawn(move || rt.train_exec("tinylogreg8", false, 4).unwrap());
            s.spawn(move || rt.eval_exec("tinylogreg8", 4).unwrap());
        }
    });
    assert_eq!(rt.stats().compiles, 3);
    assert_eq!(rt.cached_executables(), 3);
    assert!(rt.stats().compile_seconds >= 0.0);
}

#[test]
fn failed_trials_are_isolated_and_ordered() {
    // A sweep over a nonexistent model: every trial must come back as an
    // ERROR, in spec order, with the sweep completing rather than
    // aborting — per-trial isolation through the worker pool.
    let rt = common::runtime();
    let run = RunSpec {
        cfg: TrainConfig::new(
            "no-such-model",
            Policy::Fixed { m: 4 },
            LrSchedule::constant(0.1, false),
            1,
        ),
        dataset: DatasetSpec::Synthetic(SyntheticSpec {
            n: 40,
            d: 8,
            noise: 0.1,
            seed: 7,
        }),
        trials: 5,
        flops_per_sample: 1.0,
    };
    let specs = TrialSpec::expand(&run);
    assert_eq!(specs.len(), 5);
    assert_eq!(specs[3].trial, 3);
    let results = TrialRunner::new(4).run(&rt, &specs);
    assert_eq!(results.len(), 5);
    for r in &results {
        let e = r.as_ref().expect_err("no-such-model cannot train");
        assert!(e.to_string().contains("no-such-model"), "{e}");
    }
    // The runtime stays usable after failed trials.
    let ok = rt.eval_exec("tinylogreg8", 4);
    assert!(ok.is_ok());
}

// ------------------------------------------------------------ layer 3

/// The acceptance gate: a policies x seeds sweep through the engine is
/// byte-identical between `jobs = 1` and `jobs = 4` on the canonical
/// record JSON (wall-clock masked — everything else must match exactly),
/// and matches the plain serial `RunSpec::run` path.  The interpreter
/// backend evaluates every trial's HLO deterministically, so this runs —
/// and gates — on every machine.
#[test]
fn sweep_records_byte_identical_serial_vs_parallel() {
    let rt = common::runtime();
    let dataset = DatasetSpec::Synthetic(SyntheticSpec {
        n: 120,
        d: 8,
        noise: 0.05,
        seed: 33,
    });
    let policies = [
        Policy::Fixed { m: 8 },
        Policy::AdaBatch {
            m0: 4,
            factor: 2,
            every: 2,
            m_max: 8,
        },
        Policy::DiveBatch {
            m0: 4,
            delta: 0.5,
            m_max: 8,
        },
    ];
    let mut specs = Vec::new();
    let mut runs = Vec::new();
    for p in policies {
        let run = RunSpec {
            cfg: TrainConfig::new(
                "tinylogreg8",
                p,
                LrSchedule::constant(0.3, true),
                4,
            ),
            dataset: dataset.clone(),
            trials: 2,
            flops_per_sample: 1e3,
        };
        specs.extend(TrialSpec::expand(&run));
        runs.push(run);
    }
    assert_eq!(specs.len(), 6); // 3 policies x 2 seeds

    let serial: Vec<String> = TrialRunner::new(1)
        .run(&rt, &specs)
        .into_iter()
        .map(|r| r.unwrap().to_canonical_json().to_string())
        .collect();
    let parallel: Vec<String> = TrialRunner::new(4)
        .run(&rt, &specs)
        .into_iter()
        .map(|r| r.unwrap().to_canonical_json().to_string())
        .collect();
    assert_eq!(serial.len(), parallel.len());
    for (i, (a, b)) in serial.iter().zip(&parallel).enumerate() {
        assert_eq!(a, b, "trial {i} ({}) diverged across jobs levels", specs[i].label());
    }

    // And the engine path agrees with the classic serial RunSpec loop.
    let mut via_runspec = Vec::new();
    for run in &runs {
        for rec in run.run(&rt).unwrap() {
            via_runspec.push(rec.to_canonical_json().to_string());
        }
    }
    assert_eq!(serial, via_runspec);
}

/// `RunSpec::run_jobs` is the engine-backed public entry point the CLI
/// and examples use; same equivalence, arm-level.
#[test]
fn run_jobs_matches_run() {
    let rt = common::runtime();
    let run = RunSpec {
        cfg: TrainConfig::new(
            "tinylogreg8",
            Policy::DiveBatch {
                m0: 4,
                delta: 0.5,
                m_max: 8,
            },
            LrSchedule::constant(0.3, false),
            3,
        ),
        dataset: DatasetSpec::Synthetic(SyntheticSpec {
            n: 100,
            d: 8,
            noise: 0.05,
            seed: 5,
        }),
        trials: 4,
        flops_per_sample: 1e3,
    };
    let a: Vec<String> = run
        .run(&rt)
        .unwrap()
        .iter()
        .map(|r| r.to_canonical_json().to_string())
        .collect();
    let b: Vec<String> = run
        .run_jobs(&rt, 4)
        .unwrap()
        .iter()
        .map(|r| r.to_canonical_json().to_string())
        .collect();
    assert_eq!(a, b);
    // Trial order is the seed order.
    let seeds: Vec<u64> = run.run_jobs(&rt, 3).unwrap().iter().map(|r| r.seed).collect();
    assert_eq!(seeds, vec![0, 1, 2, 3]);
}
