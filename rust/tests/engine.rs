//! Trial-engine + thread-safe-runtime acceptance tests.
//!
//! Three layers, by environment requirement:
//!
//! 1. **Always run** — static `Send + Sync` assertions (the compile-time
//!    guarantee that one `Runtime` may be shared across engine workers)
//!    and engine scheduling tests over fabricated trial specs.
//! 2. **Compile-only** — concurrent compile-once semantics of the
//!    executable cache.  Runs over fake artifacts under the vendored
//!    `xla` stub (which compiles-but-cannot-execute), or over the real
//!    tiny artifacts when a real backend is linked.
//! 3. **Execution** — the serial-vs-parallel equivalence gate: a
//!    policies x seeds sweep produces byte-identical canonical records
//!    at `jobs = 1` and `jobs = 4`.  Skips (with a stderr note) without
//!    `make artifacts-tiny` + a real backend.

mod common;

use std::sync::Arc;

use divebatch::config::{DatasetSpec, RunSpec};
use divebatch::coordinator::{LrSchedule, Policy, TrainConfig};
use divebatch::data::SyntheticSpec;
use divebatch::engine::{TrialRunner, TrialSpec};
use divebatch::runtime::{Executable, Runtime};

// ------------------------------------------------------------ layer 1

/// Compile-enforced: these types cross (or are shared between) engine
/// worker threads.  If any stops being thread-safe, this test fails to
/// COMPILE rather than at runtime.
#[test]
fn runtime_layer_is_send_and_sync() {
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Runtime>();
    assert_send_sync::<Executable>();
    assert_send_sync::<Arc<Executable>>();
    assert_send_sync::<TrainConfig>();
    assert_send_sync::<divebatch::coordinator::PolicyHandle>();
    assert_send_sync::<RunSpec>();
    assert_send_sync::<TrialSpec>();
    assert_send_sync::<TrialRunner>();
    assert_send_sync::<divebatch::RunRecord>();
    assert_send_sync::<divebatch::engine::TrialError>();
}

// ------------------------------------------------------------ layer 2

/// A minimal-but-valid manifest over throwaway HLO text files, written
/// to a fresh temp dir.  Under the stub backend these entries *compile*
/// (the stub retains the text), which is all the cache tests need.
fn fake_artifacts(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "divebatch-engine-test-{}-{tag}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let entry = |file: &str| {
        format!(
            r#"{{"file": "{file}", "hlo_bytes": 20,
                "inputs": [{{"name": "params", "dtype": "f32", "shape": [9]}},
                           {{"name": "x", "dtype": "f32", "shape": [4, 8]}},
                           {{"name": "y", "dtype": "f32", "shape": [4]}},
                           {{"name": "w", "dtype": "f32", "shape": [4]}}],
                "outputs": [{{"name": "loss_sum", "dtype": "f32", "shape": []}},
                            {{"name": "correct", "dtype": "f32", "shape": []}}]}}"#
        )
    };
    let manifest = format!(
        r#"{{"version": 1, "models": {{"m8": {{
            "param_count": 9,
            "input_shape": [8],
            "label_dtype": "f32",
            "num_classes": 2,
            "ladder": [4],
            "chunk": 4,
            "tags": ["fake"],
            "param_specs": [{{"name": "w", "shape": [8]}}, {{"name": "b", "shape": [1]}}],
            "init_params": ["m8/init_s0.bin"],
            "entries": {{
                "train_div_b4": {e1},
                "train_plain_b4": {e2},
                "eval_b4": {e3}
            }}}}}}}}"#,
        e1 = entry("m8/train_div_b4.hlo.txt"),
        e2 = entry("m8/train_plain_b4.hlo.txt"),
        e3 = entry("m8/eval_b4.hlo.txt"),
    );
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    let model_dir = dir.join("m8");
    std::fs::create_dir_all(&model_dir).unwrap();
    for f in ["train_div_b4.hlo.txt", "train_plain_b4.hlo.txt", "eval_b4.hlo.txt"] {
        std::fs::write(model_dir.join(f), "HloModule fake_entry").unwrap();
    }
    dir
}

/// A runtime whose entries can at least COMPILE, plus the model name to
/// use: fake artifacts under the stub, the real tiny artifacts under a
/// real backend (skipping if they're absent).
fn compile_capable_runtime(tag: &str) -> Option<(Runtime, &'static str)> {
    // Probe the backend with a throwaway client-only runtime.
    let fake_dir = fake_artifacts(tag);
    let fake_rt = Runtime::load(&fake_dir).unwrap();
    if !fake_rt.has_execution_backend() {
        return Some((fake_rt, "m8"));
    }
    let _ = std::fs::remove_dir_all(&fake_dir);
    match Runtime::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")) {
        Ok(rt) => Some((rt, "tinylogreg8")),
        Err(e) => {
            eprintln!("skipping: real backend but artifacts missing ({e:#})");
            None
        }
    }
}

#[test]
fn concurrent_first_access_compiles_exactly_once() {
    let Some((rt, model)) = compile_capable_runtime("once") else {
        return;
    };
    assert_eq!(rt.stats().compiles, 0);
    let rt = &rt;
    let handles: Vec<Arc<Executable>> = std::thread::scope(|s| {
        let workers: Vec<_> = (0..8)
            .map(|_| s.spawn(move || rt.train_exec(model, true, 4).unwrap()))
            .collect();
        workers.into_iter().map(|w| w.join().unwrap()).collect()
    });
    // Exactly one compile happened, and everyone shares the same object.
    assert_eq!(rt.stats().compiles, 1);
    assert_eq!(rt.cached_executables(), 1);
    for h in &handles[1..] {
        assert!(Arc::ptr_eq(&handles[0], h));
    }
    // Subsequent lookups hit the fast path.
    let again = rt.train_exec(model, true, 4).unwrap();
    assert!(Arc::ptr_eq(&handles[0], &again));
    assert_eq!(rt.stats().compiles, 1);
}

#[test]
fn distinct_entries_compile_concurrently_once_each() {
    let Some((rt, model)) = compile_capable_runtime("distinct") else {
        return;
    };
    let rt = &rt;
    std::thread::scope(|s| {
        // 3 distinct entries x 4 racing threads each.
        for _ in 0..4 {
            s.spawn(move || rt.train_exec(model, true, 4).unwrap());
            s.spawn(move || rt.train_exec(model, false, 4).unwrap());
            s.spawn(move || rt.eval_exec(model, 4).unwrap());
        }
    });
    assert_eq!(rt.stats().compiles, 3);
    assert_eq!(rt.cached_executables(), 3);
    assert!(rt.stats().compile_seconds >= 0.0);
}

#[test]
fn failed_trials_are_isolated_and_ordered() {
    // Over fake artifacts the trials cannot execute (stub) or even load
    // real init params — every trial must come back as an ERROR, in spec
    // order, with the sweep completing rather than aborting.  Under a
    // real backend this exercises the same path via the missing-model
    // error instead.
    let Some((rt, _)) = compile_capable_runtime("isolated") else {
        return;
    };
    let run = RunSpec {
        cfg: TrainConfig::new(
            "no-such-model",
            Policy::Fixed { m: 4 },
            LrSchedule::constant(0.1, false),
            1,
        ),
        dataset: DatasetSpec::Synthetic(SyntheticSpec {
            n: 40,
            d: 8,
            noise: 0.1,
            seed: 7,
        }),
        trials: 5,
        flops_per_sample: 1.0,
    };
    let specs = TrialSpec::expand(&run);
    assert_eq!(specs.len(), 5);
    assert_eq!(specs[3].trial, 3);
    let results = TrialRunner::new(4).run(&rt, &specs);
    assert_eq!(results.len(), 5);
    for r in &results {
        let e = r.as_ref().expect_err("no-such-model cannot train");
        assert!(e.to_string().contains("no-such-model"), "{e}");
    }
    // The runtime stays usable after failed trials.
    assert!(rt.cached_executables() <= 3);
}

// ------------------------------------------------------------ layer 3

/// The acceptance gate: a policies x seeds sweep through the engine is
/// byte-identical between `jobs = 1` and `jobs = 4` on the canonical
/// record JSON (wall-clock masked — everything else must match exactly),
/// and matches the plain serial `RunSpec::run` path.
#[test]
fn sweep_records_byte_identical_serial_vs_parallel() {
    let Some(rt) = common::runtime() else {
        return;
    };
    let dataset = DatasetSpec::Synthetic(SyntheticSpec {
        n: 120,
        d: 8,
        noise: 0.05,
        seed: 33,
    });
    let policies = [
        Policy::Fixed { m: 8 },
        Policy::AdaBatch {
            m0: 4,
            factor: 2,
            every: 2,
            m_max: 8,
        },
        Policy::DiveBatch {
            m0: 4,
            delta: 0.5,
            m_max: 8,
        },
    ];
    let mut specs = Vec::new();
    let mut runs = Vec::new();
    for p in policies {
        let run = RunSpec {
            cfg: TrainConfig::new(
                "tinylogreg8",
                p,
                LrSchedule::constant(0.3, true),
                4,
            ),
            dataset: dataset.clone(),
            trials: 2,
            flops_per_sample: 1e3,
        };
        specs.extend(TrialSpec::expand(&run));
        runs.push(run);
    }
    assert_eq!(specs.len(), 6); // 3 policies x 2 seeds

    let serial: Vec<String> = TrialRunner::new(1)
        .run(&rt, &specs)
        .into_iter()
        .map(|r| r.unwrap().to_canonical_json().to_string())
        .collect();
    let parallel: Vec<String> = TrialRunner::new(4)
        .run(&rt, &specs)
        .into_iter()
        .map(|r| r.unwrap().to_canonical_json().to_string())
        .collect();
    assert_eq!(serial.len(), parallel.len());
    for (i, (a, b)) in serial.iter().zip(&parallel).enumerate() {
        assert_eq!(a, b, "trial {i} ({}) diverged across jobs levels", specs[i].label());
    }

    // And the engine path agrees with the classic serial RunSpec loop.
    let mut via_runspec = Vec::new();
    for run in &runs {
        for rec in run.run(&rt).unwrap() {
            via_runspec.push(rec.to_canonical_json().to_string());
        }
    }
    assert_eq!(serial, via_runspec);
}

/// `RunSpec::run_jobs` is the engine-backed public entry point the CLI
/// and examples use; same equivalence, arm-level.
#[test]
fn run_jobs_matches_run() {
    let Some(rt) = common::runtime() else {
        return;
    };
    let run = RunSpec {
        cfg: TrainConfig::new(
            "tinylogreg8",
            Policy::DiveBatch {
                m0: 4,
                delta: 0.5,
                m_max: 8,
            },
            LrSchedule::constant(0.3, false),
            3,
        ),
        dataset: DatasetSpec::Synthetic(SyntheticSpec {
            n: 100,
            d: 8,
            noise: 0.05,
            seed: 5,
        }),
        trials: 4,
        flops_per_sample: 1e3,
    };
    let a: Vec<String> = run
        .run(&rt)
        .unwrap()
        .iter()
        .map(|r| r.to_canonical_json().to_string())
        .collect();
    let b: Vec<String> = run
        .run_jobs(&rt, 4)
        .unwrap()
        .iter()
        .map(|r| r.to_canonical_json().to_string())
        .collect();
    assert_eq!(a, b);
    // Trial order is the seed order.
    let seeds: Vec<u64> = run.run_jobs(&rt, 3).unwrap().iter().map(|r| r.seed).collect();
    assert_eq!(seeds, vec![0, 1, 2, 3]);
}
