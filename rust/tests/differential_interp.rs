//! Three-way differential gate for the compiled interpreter.  Every
//! committed fixture entry — over the jax golden inputs AND over
//! randomized inputs — is run through all three execution paths:
//!
//! 1. the compiled SIMD tier (8-lane kernels, cost-model dot plans),
//! 2. the compiled scalar tier (`InterpTier::Scalar`, the
//!    `DIVEBATCH_INTERP_TIER=scalar` escape hatch), and
//! 3. the retained tree-walk reference evaluator.
//!
//! The two compiled tiers implement the same pinned 8-lane accumulation
//! contract and must agree **bit for bit** (`to_bits` equality) — any
//! divergence means a tier broke the contract.  Compiled-vs-reference is
//! compared to 1e-6/1e-5 (mixed absolute/relative): the paths
//! intentionally differ in transcendental math (compiled: deterministic
//! in-crate fmath kernels; reference: platform libm) and in dot/reduce
//! association order, so bitwise equality is not expected there —
//! agreement within a few ulps of f32 is.  A real lowering bug (wrong
//! stride map, bad slot reuse, broken fusion, mis-ordered reduce)
//! produces errors orders of magnitude above the tolerance and fails
//! here entry by entry.  Odd, non-multiple-of-8 shapes get a dedicated
//! inline-HLO case so lane-tail handling is exercised even if every
//! fixture model keeps 8-aligned dims.

mod common;

use divebatch::runtime::{Dtype, TensorSpec};
use divebatch::util::json;
use divebatch::util::rng::Rng;
use divebatch::Manifest;

fn fixtures_manifest() -> Manifest {
    Manifest::load(common::fixtures_dir()).expect("committed fixtures")
}

/// Compile one entry through the interp backend (both paths share the
/// compiled object).
fn compile(manifest: &Manifest, file: &str) -> xla::PjRtLoadedExecutable {
    let path = manifest.path(file);
    let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap()).unwrap();
    let comp = xla::XlaComputation::from_proto(&proto);
    xla::PjRtClient::interp().compile(&comp).unwrap()
}

fn decompose(result: Vec<Vec<xla::PjRtBuffer>>) -> Vec<xla::Literal> {
    let mut tuple = result[0][0].to_literal_sync().unwrap();
    match tuple.decompose_tuple() {
        Ok(parts) => parts,
        Err(_) => vec![tuple],
    }
}

fn assert_close(compiled: &[xla::Literal], reference: &[xla::Literal], tol: f64, tag: &str) {
    assert_eq!(compiled.len(), reference.len(), "{tag}: output arity");
    for (ix, (c, r)) in compiled.iter().zip(reference).enumerate() {
        if let (Ok(cv), Ok(rv)) = (c.to_vec::<f32>(), r.to_vec::<f32>()) {
            assert_eq!(cv.len(), rv.len(), "{tag}[{ix}] length");
            for (j, (a, b)) in cv.iter().zip(&rv).enumerate() {
                let (a, b) = (*a as f64, *b as f64);
                if a.is_nan() && b.is_nan() {
                    continue;
                }
                assert!(
                    (a - b).abs() <= tol * (1.0 + b.abs()),
                    "{tag}[{ix}][{j}]: compiled {a} vs reference {b}"
                );
            }
        } else {
            let cv = c.to_vec::<i32>().unwrap();
            let rv = r.to_vec::<i32>().unwrap();
            assert_eq!(cv, rv, "{tag}[{ix}] (i32)");
        }
    }
}

/// The two compiled tiers share one numeric contract: equality is exact,
/// bit for bit, including NaN payloads.
fn assert_bitwise(simd: &[xla::Literal], scalar: &[xla::Literal], tag: &str) {
    assert_eq!(simd.len(), scalar.len(), "{tag}: tier output arity");
    for (ix, (a, b)) in simd.iter().zip(scalar).enumerate() {
        if let (Ok(av), Ok(bv)) = (a.to_vec::<f32>(), b.to_vec::<f32>()) {
            assert_eq!(av.len(), bv.len(), "{tag}[{ix}] length");
            for (j, (x, y)) in av.iter().zip(&bv).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{tag}[{ix}][{j}]: simd {x} vs scalar {y}"
                );
            }
        } else {
            let av = a.to_vec::<i32>().unwrap();
            let bv = b.to_vec::<i32>().unwrap();
            assert_eq!(av, bv, "{tag}[{ix}] (i32)");
        }
    }
}

/// Run one input set through all three paths and apply both gates.
fn assert_three_way(exe: &xla::PjRtLoadedExecutable, inputs: &[xla::Literal], tol: f64, tag: &str) {
    let simd = decompose(exe.execute_with_tier(inputs, xla::InterpTier::Simd).unwrap());
    let scalar = decompose(
        exe.execute_with_tier(inputs, xla::InterpTier::Scalar)
            .unwrap(),
    );
    let reference = decompose(exe.execute_reference(inputs).unwrap());
    assert_bitwise(&simd, &scalar, tag);
    assert_close(&simd, &reference, tol, tag);
}

/// Tolerance for the committed jax golden inputs (the ISSUE-4 acceptance
/// bar).
const GOLDEN_TOL: f64 = 1e-6;
/// Tolerance for randomized draws: fmath-vs-libm differs by ~1 ulp per
/// transcendental, and a batch-summed output whose true value cancels
/// toward zero can accumulate several aligned ulps — a slightly wider
/// floor keeps the gate meaningful without seed/libc flakes.
const RANDOM_TOL: f64 = 1e-5;

/// Build one randomized input literal for a tensor spec.  Values stay in
/// a moderate range so paths through exp/log1p are exercised without
/// drowning the comparison in overflow-generated infs.
fn random_input(spec: &TensorSpec, rng: &mut Rng) -> xla::Literal {
    let n = spec.elements();
    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
    match spec.dtype {
        Dtype::F32 => {
            let v: Vec<f32> = (0..n).map(|_| rng.uniform(-3.0, 3.0) as f32).collect();
            xla::Literal::vec1(&v).reshape(&dims).unwrap()
        }
        Dtype::S32 => {
            let v: Vec<i32> = (0..n).map(|_| rng.range(0, 4) as i32).collect();
            xla::Literal::vec1(&v).reshape(&dims).unwrap()
        }
    }
}

/// Every entry of every fixture model, on the committed jax golden
/// inputs: SIMD == scalar bitwise, compiled == reference within tol.
#[test]
fn compiled_matches_reference_on_golden_inputs() {
    let manifest = fixtures_manifest();
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/golden_entry_outputs.json"
    );
    let doc = json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
    let models = doc.req("models").unwrap().as_obj().unwrap();
    assert!(models.len() >= 4, "expected goldens for the full model zoo");
    for (model_name, model_doc) in models {
        let model = manifest.model(model_name).unwrap();
        let entries = model_doc.as_obj().unwrap();
        assert!(entries.len() >= 7, "{model_name}: expected all entries covered");
        for (key, case) in entries {
            let info = model.entry(key).unwrap();
            let exe = compile(&manifest, &info.file);
            let inputs: Vec<xla::Literal> = case
                .req_arr("inputs")
                .unwrap()
                .iter()
                .zip(&info.inputs)
                .map(|(j, spec)| golden_literal(j, spec))
                .collect();
            assert_three_way(&exe, &inputs, GOLDEN_TOL, &format!("{model_name}/{key}"));
        }
    }
}

/// Build one golden input literal in the entry's declared dtype (the
/// golden json stores every input as floats; tinyresnet4 labels are s32).
fn golden_literal(j: &json::Json, spec: &TensorSpec) -> xla::Literal {
    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
    let vals = j.as_arr().unwrap().iter().map(|x| x.as_f64().unwrap());
    match spec.dtype {
        Dtype::F32 => {
            let v: Vec<f32> = vals.map(|x| x as f32).collect();
            xla::Literal::vec1(&v).reshape(&dims).unwrap()
        }
        Dtype::S32 => {
            let v: Vec<i32> = vals.map(|x| x as i32).collect();
            xla::Literal::vec1(&v).reshape(&dims).unwrap()
        }
    }
}

/// Property test: randomized inputs (seeded draws per entry) through all
/// three paths, on every fixture model — the logreg pair (steplogreg8's
/// 64-row entries are the step-parallel bench's workload), the MLP, and
/// the conv resnet (fewer draws: its reference-path convolutions are the
/// slow leg, and each draw already covers every conv/while/dynamic-slice
/// site in the entry).
#[test]
fn compiled_matches_reference_on_randomized_inputs() {
    let manifest = fixtures_manifest();
    let mut rng = Rng::new(0xD1FF);
    for (model_name, draws) in [
        ("tinylogreg8", 16),
        ("steplogreg8", 16),
        ("tinymlp8", 16),
        ("tinyresnet4", 4),
        // The conv-dominated mid-tier model: 2 draws keep the slow
        // reference-evaluator leg affordable while still covering every
        // blocked-conv site.
        ("tinyresnet8", 2),
    ] {
        let model = manifest.model(model_name).unwrap();
        for (key, info) in &model.entries {
            let exe = compile(&manifest, &info.file);
            for trial in 0..draws {
                let inputs: Vec<xla::Literal> = info
                    .inputs
                    .iter()
                    .map(|spec| random_input(spec, &mut rng))
                    .collect();
                assert_three_way(&exe, &inputs, RANDOM_TOL, &format!("{model_name}/{key}#{trial}"));
            }
        }
    }
}

/// Odd, non-multiple-of-8 shapes (k=11, n=13, m=3): every fixture model
/// keeps 8-aligned dims, so this inline module is what actually drives
/// the lane-tail paths of every dot variant and the grouped-reduce
/// remainder loop through the integration-level three-way gate.
#[test]
fn three_way_agreement_on_odd_shapes() {
    let text = r#"
HloModule odd

region_0.1 {
  Arg_0.2 = f32[] parameter(0)
  Arg_1.3 = f32[] parameter(1)
  ROOT add.4 = f32[] add(Arg_0.2, Arg_1.3)
}

ENTRY main.14 {
  Arg_0.1 = f32[3,11]{1,0} parameter(0)
  Arg_1.2 = f32[11]{0} parameter(1)
  Arg_2.3 = f32[3,13]{1,0} parameter(2)
  dot.4 = f32[3]{0} dot(Arg_0.1, Arg_1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  exponential.5 = f32[3]{0} exponential(dot.4)
  constant.6 = f32[] constant(0.5)
  reduce.7 = f32[] reduce(exponential.5, constant.6), dimensions={0}, to_apply=region_0.1
  reduce.8 = f32[3]{0} reduce(Arg_2.3, constant.6), dimensions={1}, to_apply=region_0.1
  reduce.9 = f32[13]{0} reduce(Arg_2.3, constant.6), dimensions={0}, to_apply=region_0.1
  dot.10 = f32[11,13]{1,0} dot(Arg_0.1, Arg_2.3), lhs_contracting_dims={0}, rhs_contracting_dims={0}
  ROOT tuple.11 = (f32[3]{0}, f32[], f32[3]{0}, f32[13]{0}, f32[11,13]{1,0}) tuple(dot.4, reduce.7, reduce.8, reduce.9, dot.10)
}
"#;
    let proto = xla::HloModuleProto::from_text(text);
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = xla::PjRtClient::interp().compile(&comp).unwrap();
    let spec = |shape: &[usize]| TensorSpec {
        name: String::new(),
        dtype: Dtype::F32,
        shape: shape.to_vec(),
    };
    let mut rng = Rng::new(0x0DD5);
    for trial in 0..8 {
        let inputs = vec![
            random_input(&spec(&[3, 11]), &mut rng),
            random_input(&spec(&[11]), &mut rng),
            random_input(&spec(&[3, 13]), &mut rng),
        ];
        assert_three_way(&exe, &inputs, RANDOM_TOL, &format!("odd#{trial}"));
    }
}

/// Odd convolution geometries — grouped + strided + asymmetric padding,
/// 1x1, K not divisible by 8, and an lhs-dilated (transposed) conv like
/// the input-gradient of a strided forward conv — compiled under both
/// forced conv strategies (`DIVEBATCH_CONV_ALGO=blocked|im2col`).  The two lowerings
/// must agree **bit for bit** on both tiers (the pinned lanes contract
/// over the shared patch K order), and each must pass the three-way gate
/// against the reference evaluator, which convolves by a deliberately
/// different direct algorithm.
#[test]
fn three_way_agreement_on_odd_conv_geometries() {
    let text = r#"
HloModule oddconv

ENTRY main.14 {
  Arg_0.1 = f32[2,9,9,6]{3,2,1,0} parameter(0)
  Arg_1.2 = f32[3,3,2,6]{3,2,1,0} parameter(1)
  Arg_2.3 = f32[2,5,5,7]{3,2,1,0} parameter(2)
  Arg_3.4 = f32[1,1,7,9]{3,2,1,0} parameter(3)
  Arg_4.5 = f32[1,6,6,3]{3,2,1,0} parameter(4)
  Arg_5.6 = f32[3,3,3,5]{3,2,1,0} parameter(5)
  Arg_6.7 = f32[1,4,4,2]{3,2,1,0} parameter(6)
  Arg_7.8 = f32[3,3,2,3]{3,2,1,0} parameter(7)
  convolution.9 = f32[2,4,5,6]{3,2,1,0} convolution(Arg_0.1, Arg_1.2), window={size=3x3 stride=2x2 pad=0_1x2_0}, dim_labels=b01f_01io->b01f, feature_group_count=3
  convolution.10 = f32[2,5,5,9]{3,2,1,0} convolution(Arg_2.3, Arg_3.4), window={size=1x1 pad=0_0x0_0}, dim_labels=b01f_01io->b01f, feature_group_count=1
  convolution.11 = f32[1,6,6,5]{3,2,1,0} convolution(Arg_4.5, Arg_5.6), window={size=3x3 pad=1_1x1_1}, dim_labels=b01f_01io->b01f, feature_group_count=1
  convolution.12 = f32[1,8,8,3]{3,2,1,0} convolution(Arg_6.7, Arg_7.8), window={size=3x3 pad=2_1x1_2 lhs_dilate=2x2}, dim_labels=b01f_01io->b01f, feature_group_count=1
  ROOT tuple.13 = (f32[2,4,5,6]{3,2,1,0}, f32[2,5,5,9]{3,2,1,0}, f32[1,6,6,5]{3,2,1,0}, f32[1,8,8,3]{3,2,1,0}) tuple(convolution.9, convolution.10, convolution.11, convolution.12)
}
"#;
    let spec = |shape: &[usize]| TensorSpec {
        name: String::new(),
        dtype: Dtype::F32,
        shape: shape.to_vec(),
    };
    let compile_forced = |force: &str| {
        // Strategy-only knob, read at compile time: concurrent tests that
        // compile convs while it is set merely get the forced strategy,
        // which by the contract cannot change their bits.
        std::env::set_var("DIVEBATCH_CONV_ALGO", force);
        let proto = xla::HloModuleProto::from_text(text);
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = xla::PjRtClient::interp().compile(&comp).unwrap();
        std::env::remove_var("DIVEBATCH_CONV_ALGO");
        exe
    };
    let blocked = compile_forced("blocked");
    let im2col = compile_forced("im2col");
    let shapes: [&[usize]; 8] = [
        &[2, 9, 9, 6],
        &[3, 3, 2, 6],
        &[2, 5, 5, 7],
        &[1, 1, 7, 9],
        &[1, 6, 6, 3],
        &[3, 3, 3, 5],
        &[1, 4, 4, 2],
        &[3, 3, 2, 3],
    ];
    let mut rng = Rng::new(0xC0DD);
    for trial in 0..4 {
        let inputs: Vec<xla::Literal> = shapes
            .iter()
            .map(|s| random_input(&spec(s), &mut rng))
            .collect();
        assert_three_way(&blocked, &inputs, RANDOM_TOL, &format!("oddconv-blocked#{trial}"));
        assert_three_way(&im2col, &inputs, RANDOM_TOL, &format!("oddconv-im2col#{trial}"));
        for tier in [xla::InterpTier::Simd, xla::InterpTier::Scalar] {
            let a = decompose(blocked.execute_with_tier(&inputs, tier).unwrap());
            let b = decompose(im2col.execute_with_tier(&inputs, tier).unwrap());
            assert_bitwise(&a, &b, &format!("oddconv blocked-vs-im2col#{trial}"));
        }
    }
}

/// Conv programs stay allocation-flat in steady state too — whether
/// every conv picked the blocked kernel (no conv scratch reserved at
/// all) or some still take im2col through the shared scratch slots.
#[test]
fn arena_stays_flat_on_conv_model() {
    let manifest = fixtures_manifest();
    let model = manifest.model("tinyresnet4").unwrap();
    let info = model.entry("train_div_b8").unwrap();
    let exe = compile(&manifest, &info.file);
    let mut rng = Rng::new(11);
    let inputs: Vec<xla::Literal> = info
        .inputs
        .iter()
        .map(|spec| random_input(spec, &mut rng))
        .collect();
    for _ in 0..20 {
        exe.execute(&inputs).unwrap();
    }
    let (created, grown) = exe.interp_arena_stats().unwrap();
    assert_eq!(created, 1, "serial steady state must reuse one arena");
    assert_eq!(grown, 0, "slots (incl. conv scratch) are sized at compile time");
}

/// Steady-state execution reuses one arena and never regrows buffers —
/// the allocs-proxy the perf bench records must stay flat in tests too.
#[test]
fn arena_stays_flat_across_repeated_execution() {
    let manifest = fixtures_manifest();
    let model = manifest.model("tinylogreg8").unwrap();
    let info = model.entry("train_div_b8").unwrap();
    let exe = compile(&manifest, &info.file);
    let mut rng = Rng::new(7);
    let inputs: Vec<xla::Literal> = info
        .inputs
        .iter()
        .map(|spec| random_input(spec, &mut rng))
        .collect();
    for _ in 0..50 {
        exe.execute(&inputs).unwrap();
    }
    let (created, grown) = exe.interp_arena_stats().unwrap();
    assert_eq!(created, 1, "serial steady state must reuse one arena");
    assert_eq!(grown, 0, "slots are sized at compile time");
}
