//! Differential gate for the compiled interpreter: the register-program
//! path must agree with the retained tree-walk reference evaluator on
//! every committed fixture entry — over the jax golden inputs AND over
//! randomized inputs — to 1e-6 (mixed absolute/relative).
//!
//! The two paths intentionally differ in transcendental math (compiled:
//! deterministic in-crate fmath kernels; reference: platform libm), so
//! bitwise equality is not expected — agreement within ~1 ulp of f32 is.
//! A real lowering bug (wrong stride map, bad slot reuse, broken fusion,
//! mis-ordered reduce) produces errors orders of magnitude above the
//! tolerance and fails here entry by entry.

mod common;

use divebatch::runtime::{Dtype, TensorSpec};
use divebatch::util::json;
use divebatch::util::rng::Rng;
use divebatch::Manifest;

fn fixtures_manifest() -> Manifest {
    Manifest::load(common::fixtures_dir()).expect("committed fixtures")
}

/// Compile one entry through the interp backend (both paths share the
/// compiled object).
fn compile(manifest: &Manifest, file: &str) -> xla::PjRtLoadedExecutable {
    let path = manifest.path(file);
    let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap()).unwrap();
    let comp = xla::XlaComputation::from_proto(&proto);
    xla::PjRtClient::interp().compile(&comp).unwrap()
}

fn decompose(result: Vec<Vec<xla::PjRtBuffer>>) -> Vec<xla::Literal> {
    let mut tuple = result[0][0].to_literal_sync().unwrap();
    match tuple.decompose_tuple() {
        Ok(parts) => parts,
        Err(_) => vec![tuple],
    }
}

fn assert_close(compiled: &[xla::Literal], reference: &[xla::Literal], tol: f64, tag: &str) {
    assert_eq!(compiled.len(), reference.len(), "{tag}: output arity");
    for (ix, (c, r)) in compiled.iter().zip(reference).enumerate() {
        if let (Ok(cv), Ok(rv)) = (c.to_vec::<f32>(), r.to_vec::<f32>()) {
            assert_eq!(cv.len(), rv.len(), "{tag}[{ix}] length");
            for (j, (a, b)) in cv.iter().zip(&rv).enumerate() {
                let (a, b) = (*a as f64, *b as f64);
                if a.is_nan() && b.is_nan() {
                    continue;
                }
                assert!(
                    (a - b).abs() <= tol * (1.0 + b.abs()),
                    "{tag}[{ix}][{j}]: compiled {a} vs reference {b}"
                );
            }
        } else {
            let cv = c.to_vec::<i32>().unwrap();
            let rv = r.to_vec::<i32>().unwrap();
            assert_eq!(cv, rv, "{tag}[{ix}] (i32)");
        }
    }
}

/// Tolerance for the committed jax golden inputs (the ISSUE-4 acceptance
/// bar).
const GOLDEN_TOL: f64 = 1e-6;
/// Tolerance for randomized draws: fmath-vs-libm differs by ~1 ulp per
/// transcendental, and a batch-summed output whose true value cancels
/// toward zero can accumulate several aligned ulps — a slightly wider
/// floor keeps the gate meaningful without seed/libc flakes.
const RANDOM_TOL: f64 = 1e-5;

/// Build one randomized input literal for a tensor spec.  Values stay in
/// a moderate range so paths through exp/log1p are exercised without
/// drowning the comparison in overflow-generated infs.
fn random_input(spec: &TensorSpec, rng: &mut Rng) -> xla::Literal {
    let n = spec.elements();
    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
    match spec.dtype {
        Dtype::F32 => {
            let v: Vec<f32> = (0..n).map(|_| rng.uniform(-3.0, 3.0) as f32).collect();
            xla::Literal::vec1(&v).reshape(&dims).unwrap()
        }
        Dtype::S32 => {
            let v: Vec<i32> = (0..n).map(|_| rng.range(0, 4) as i32).collect();
            xla::Literal::vec1(&v).reshape(&dims).unwrap()
        }
    }
}

/// Every entry of every fixture model, on the committed jax golden
/// inputs: compiled path == reference path.
#[test]
fn compiled_matches_reference_on_golden_inputs() {
    let manifest = fixtures_manifest();
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/golden_entry_outputs.json"
    );
    let doc = json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
    let models = doc.req("models").unwrap().as_obj().unwrap();
    assert!(models.len() >= 2, "expected goldens for both fixture models");
    for (model_name, model_doc) in models {
        let model = manifest.model(model_name).unwrap();
        let entries = model_doc.as_obj().unwrap();
        assert!(entries.len() >= 7, "{model_name}: expected all entries covered");
        for (key, case) in entries {
            let info = model.entry(key).unwrap();
            let exe = compile(&manifest, &info.file);
            let inputs: Vec<xla::Literal> = case
                .req_arr("inputs")
                .unwrap()
                .iter()
                .zip(&info.inputs)
                .map(|(j, spec)| {
                    let v: Vec<f32> = j
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|x| x.as_f64().unwrap() as f32)
                        .collect();
                    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(&v).reshape(&dims).unwrap()
                })
                .collect();
            let compiled_out = decompose(exe.execute(&inputs).unwrap());
            let reference_out = decompose(exe.execute_reference(&inputs).unwrap());
            assert_close(
                &compiled_out,
                &reference_out,
                GOLDEN_TOL,
                &format!("{model_name}/{key}"),
            );
        }
    }
}

/// Property test: randomized inputs (16 draws per entry, seeded) through
/// both paths, on every fixture model (steplogreg8's 64-row entries are
/// the step-parallel bench's workload).
#[test]
fn compiled_matches_reference_on_randomized_inputs() {
    let manifest = fixtures_manifest();
    let mut rng = Rng::new(0xD1FF);
    for model_name in ["tinylogreg8", "steplogreg8"] {
        let model = manifest.model(model_name).unwrap();
        for (key, info) in &model.entries {
            let exe = compile(&manifest, &info.file);
            for trial in 0..16 {
                let inputs: Vec<xla::Literal> = info
                    .inputs
                    .iter()
                    .map(|spec| random_input(spec, &mut rng))
                    .collect();
                let compiled_out = decompose(exe.execute(&inputs).unwrap());
                let reference_out = decompose(exe.execute_reference(&inputs).unwrap());
                assert_close(
                    &compiled_out,
                    &reference_out,
                    RANDOM_TOL,
                    &format!("{model_name}/{key}#{trial}"),
                );
            }
        }
    }
}

/// Steady-state execution reuses one arena and never regrows buffers —
/// the allocs-proxy the perf bench records must stay flat in tests too.
#[test]
fn arena_stays_flat_across_repeated_execution() {
    let manifest = fixtures_manifest();
    let model = manifest.model("tinylogreg8").unwrap();
    let info = model.entry("train_div_b8").unwrap();
    let exe = compile(&manifest, &info.file);
    let mut rng = Rng::new(7);
    let inputs: Vec<xla::Literal> = info
        .inputs
        .iter()
        .map(|spec| random_input(spec, &mut rng))
        .collect();
    for _ in 0..50 {
        exe.execute(&inputs).unwrap();
    }
    let (created, grown) = exe.interp_arena_stats().unwrap();
    assert_eq!(created, 1, "serial steady state must reuse one arena");
    assert_eq!(grown, 0, "slots are sized at compile time");
}
