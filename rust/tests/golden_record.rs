//! Golden-record regression gate: one fixed `(config, dataset, seed)`
//! synthetic run whose canonical record JSON is pinned byte-for-byte.
//!
//! Any numeric drift in the trainer, optimizer, diversity accumulation,
//! policy decisions, simulated-cluster timing, record serialization, or
//! the interpreter backend itself changes the canonical JSON and fails
//! this test loudly — the fixture diff then *is* the drift report.
//!
//! Blessing a new golden (after an intentional semantic change):
//!
//! ```bash
//! DIVEBATCH_BLESS=1 cargo test --test golden_record
//! git add rust/tests/fixtures/golden_run_record.json
//! ```
//!
//! The committed fixture was minted by the bit-exact Python mirror
//! (`python -m mirror.golden_run` — see python/mirror/), which reproduces
//! the whole pipeline operation for operation: xoshiro256++ streams,
//! synthetic data, micro-plans, the compiled interpreter's deterministic
//! fmath kernels, SGD, DiveBatch decisions, cluster timing, and the
//! canonical JSON writer.  The interpreter's compiled path deliberately
//! avoids platform libm (interp/fmath.rs), so this byte pin holds across
//! machines and libc versions.
//!
//! Bootstrap: if the fixture file is absent, the test writes it from
//! the current run and passes, with a loud note (a GitHub `::warning::`
//! annotation under CI) demanding the file be committed.  With the
//! fixture committed, any byte of drift fails.

mod common;

use divebatch::config::{DatasetSpec, RunSpec};
use divebatch::coordinator::{LrSchedule, Policy, TrainConfig};
use divebatch::data::SyntheticSpec;

fn golden_path() -> &'static str {
    concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/golden_run_record.json"
    )
}

/// The pinned run: DiveBatch over the synthetic-convex fixture model.
/// Every knob is explicit so the fixture is reproducible from this file
/// alone.
fn golden_run() -> String {
    let rt = common::runtime();
    let spec = RunSpec {
        cfg: TrainConfig::new(
            "tinylogreg8",
            Policy::DiveBatch {
                m0: 4,
                delta: 0.5,
                m_max: 8,
            },
            LrSchedule::constant(0.3, true),
            6,
        ),
        dataset: DatasetSpec::Synthetic(SyntheticSpec {
            n: 120,
            d: 8,
            noise: 0.05,
            seed: 33,
        }),
        trials: 1,
        flops_per_sample: 1e3,
    };
    let rec = spec.run(&rt).unwrap().into_iter().next().unwrap();
    rec.to_canonical_json().to_string()
}

#[test]
fn golden_run_record_matches_committed_fixture() {
    let got = golden_run();
    let path = golden_path();
    let bless = std::env::var("DIVEBATCH_BLESS").is_ok_and(|v| v == "1");
    match std::fs::read_to_string(path) {
        Ok(want) => {
            if bless && got != want {
                std::fs::write(path, &got).unwrap();
                eprintln!("golden_record: re-blessed {path} (commit the new fixture)");
                return;
            }
            assert_eq!(
                got, want,
                "canonical run record drifted from the committed golden \
                 ({path}); if the change is intentional, re-bless with \
                 DIVEBATCH_BLESS=1 and commit the diff"
            );
        }
        // Bootstrap applies ONLY to a genuinely absent fixture; any other
        // read failure (permissions, non-UTF8 from a botched merge) must
        // fail rather than silently re-bless a damaged baseline.
        Err(e) if e.kind() != std::io::ErrorKind::NotFound => {
            panic!("golden_record: cannot read fixture {path}: {e}");
        }
        Err(_) => {
            std::fs::write(path, &got).unwrap();
            eprintln!(
                "golden_record: no fixture at {path} — wrote one from this run; \
                 COMMIT IT so future runs gate on it"
            );
            if std::env::var("CI").is_ok() {
                // Surfaced as a GitHub Actions annotation (tests run
                // with --nocapture in CI, so this reaches the log).
                println!(
                    "::warning file=rust/tests/golden_record.rs::golden_record \
                     baseline missing — bootstrap-blessed this run; commit \
                     rust/tests/fixtures/golden_run_record.json to arm the gate"
                );
            }
        }
    }
}

/// The golden run itself is reproducible within a process: two fresh
/// trainer invocations produce byte-identical canonical JSON.  (The
/// cross-process pin is the committed fixture above.)
#[test]
fn golden_run_is_deterministic_in_process() {
    assert_eq!(golden_run(), golden_run());
}
