//! End-to-end image-classification driver (the repo's E2E validation
//! example): trains the residual CNN on the procedural CIFAR-10-like
//! dataset under all four batch-size policies and prints the Table-1-style
//! summary — accuracy milestones + time (real and simulated 4-GPU) to
//! within ±1% of final accuracy.
//!
//! This is a real training workload through every layer of the stack:
//! Rust coordinator -> PJRT executables -> JAX-lowered fwd/bwd -> Pallas
//! per-sample-gradient kernels.
//!
//! ```bash
//! cargo run --release --example cifar_like_sweep [-- --epochs 30 --per-class 50 --trials 3 --jobs 0]
//! ```
//!
//! Multi-trial arms run through the parallel trial engine
//! (`divebatch::engine`); `--jobs 0` uses every core.

use divebatch::config::presets::{realworld, Scale};
use divebatch::runtime::Runtime;
use divebatch::util::args::ArgSpec;
use divebatch::util::plot::{render, Series};
use divebatch::util::stats;
use divebatch::util::table::{pm, Table};

fn main() -> anyhow::Result<()> {
    let args = ArgSpec::new("cifar_like_sweep", "Figures 3/4 + Table 1 at example scale")
        .opt("dataset", Some("cifar10"), "cifar10 | cifar100 | tin")
        .opt("epochs", Some("20"), "epochs per arm")
        .opt("per-class", Some("40"), "images per class")
        .opt("trials", Some("1"), "trials per arm")
        .opt("jobs", Some("0"), "trial-engine worker threads (0 = all cores)")
        .flag("rescale-lr", "appendix-E lr rescaling variant")
        .parse_or_exit();

    let scale = Scale {
        epochs: args.usize("epochs"),
        trials: args.usize("trials"),
        n_synth: 0,
        per_class: args.usize("per-class"),
        image_epochs: args.usize("epochs"),
        image_trials: args.usize("trials"),
    };
    let exp = realworld(args.str("dataset"), scale, args.flag("rescale-lr"))
        .expect("dataset must be cifar10|cifar100|tin");
    println!("== {} ==\n", exp.title);

    let rt = Runtime::load_default()?;
    let mut acc_series = Vec::new();
    let mut table = Table::new(
        "Table 1 (example scale)",
        &["algorithm", "25%", "50%", "75%", "100%", "t±1% sim(s)", "t±1% wall(s)"],
    );
    // Each arm's trials fan across the trial engine (wall-clock columns
    // measure contended time under --jobs > 1; sim(s) is jobs-invariant).
    for run in &exp.runs {
        let records = run.run_jobs(&rt, args.usize("jobs"))?;
        let label = records[0].label.clone();
        eprintln!("done: {label}");
        let accs: Vec<Vec<f64>> = records.iter().map(|r| r.val_acc_curve()).collect();
        acc_series.push(Series::new(&label, stats::mean_curve(&accs)));
        let at = |f: f64| -> Vec<f64> { records.iter().map(|r| r.val_acc_at_frac(f)).collect() };
        let t_sim: Vec<f64> = records
            .iter()
            .filter_map(|r| r.time_within_final(1.0, true))
            .collect();
        let t_wall: Vec<f64> = records
            .iter()
            .filter_map(|r| r.time_within_final(1.0, false))
            .collect();
        table.row(vec![
            label,
            pm(stats::mean(&at(0.25)), stats::stderr(&at(0.25))),
            pm(stats::mean(&at(0.5)), stats::stderr(&at(0.5))),
            pm(stats::mean(&at(0.75)), stats::stderr(&at(0.75))),
            pm(stats::mean(&at(1.0)), stats::stderr(&at(1.0))),
            format!("{:.2}", stats::mean(&t_sim)),
            format!("{:.2}", stats::mean(&t_wall)),
        ]);
    }
    println!(
        "{}",
        render("validation accuracy", "epoch", &acc_series, 72, 16)
    );
    println!("{}", table.render());
    Ok(())
}
