//! Figure 2 at example scale: ORACLE (exact full-dataset gradient
//! diversity every epoch) vs DIVEBATCH (the paper's within-epoch
//! estimate).  Shows the estimate quality and how closely the two batch
//! schedules track — the paper's validation of Definition 2.
//!
//! ```bash
//! cargo run --release --example oracle_compare [-- --nonconvex]
//! ```

use divebatch::config::presets::{fig1_convex, fig1_nonconvex, Scale};
use divebatch::runtime::Runtime;
use divebatch::util::args::ArgSpec;
use divebatch::util::plot::{render, Series};

fn main() -> anyhow::Result<()> {
    let args = ArgSpec::new("oracle_compare", "Figure 2: Oracle vs DiveBatch")
        .opt("epochs", Some("20"), "epochs per run")
        .opt("n", Some("3000"), "synthetic dataset size")
        .flag("nonconvex", "use the MLP (Figure 2 bottom) instead of logreg")
        .parse_or_exit();

    let scale = Scale {
        epochs: args.usize("epochs"),
        trials: 1,
        n_synth: args.usize("n"),
        per_class: 0,
        ..Scale::quick()
    };
    // Arms 2.. of fig1 presets with oracle appended = DiveBatch + Oracle.
    let exp = if args.flag("nonconvex") {
        fig1_nonconvex(scale, true)
    } else {
        fig1_convex(scale, true)
    };
    let arms = &exp.runs[2..]; // [DiveBatch, Oracle]
    println!("== Figure 2: Oracle vs DiveBatch ({}) ==\n", if args.flag("nonconvex") { "nonconvex" } else { "convex" });

    let rt = Runtime::load_default()?;
    let mut batch_series = Vec::new();
    let mut loss_series = Vec::new();
    let mut div_series = Vec::new();
    for run in arms {
        let rec = run.run(&rt)?.into_iter().next().unwrap();
        eprintln!("done: {}", rec.label);
        batch_series.push(Series::new(&rec.label, rec.batch_size_curve()));
        loss_series.push(Series::new(&rec.label, rec.val_loss_curve()));
        let curve = if rec.policy_kind == "oracle" {
            rec.exact_delta_curve()
        } else {
            rec.delta_hat_curve()
        };
        let label = if rec.policy_kind == "oracle" {
            "exact Delta (Oracle)"
        } else {
            "estimated Delta (DiveBatch)"
        };
        div_series.push(Series::new(label, curve));
    }
    println!("{}", render("validation loss", "epoch", &loss_series, 72, 12));
    println!(
        "{}",
        render("batch size progression", "epoch", &batch_series, 72, 12)
    );
    println!(
        "{}",
        render(
            "gradient diversity (estimated vs exact)",
            "epoch",
            &div_series,
            72,
            12
        )
    );
    Ok(())
}
