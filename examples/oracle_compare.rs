//! Figure 2 at example scale: ORACLE (exact full-dataset gradient
//! diversity every epoch) vs DIVEBATCH (the paper's within-epoch
//! estimate).  Shows the estimate quality and how closely the two batch
//! schedules track — the paper's validation of Definition 2.
//!
//! ```bash
//! cargo run --release --example oracle_compare [-- --nonconvex --jobs 2]
//! ```
//!
//! The two arms (DiveBatch, Oracle) run concurrently on the parallel
//! trial engine — the Oracle's exact full-dataset passes no longer
//! serialize behind the DiveBatch arm.

use divebatch::config::presets::{fig1_convex, fig1_nonconvex, Scale};
use divebatch::engine::{TrialRunner, TrialSpec};
use divebatch::runtime::Runtime;
use divebatch::util::args::ArgSpec;
use divebatch::util::plot::{render, Series};

fn main() -> anyhow::Result<()> {
    let args = ArgSpec::new("oracle_compare", "Figure 2: Oracle vs DiveBatch")
        .opt("epochs", Some("20"), "epochs per run")
        .opt("n", Some("3000"), "synthetic dataset size")
        .opt("jobs", Some("0"), "trial-engine worker threads (0 = all cores)")
        .flag("nonconvex", "use the MLP (Figure 2 bottom) instead of logreg")
        .parse_or_exit();

    let scale = Scale {
        epochs: args.usize("epochs"),
        trials: 1,
        n_synth: args.usize("n"),
        per_class: 0,
        ..Scale::quick()
    };
    // Arms 2.. of fig1 presets with oracle appended = DiveBatch + Oracle.
    let exp = if args.flag("nonconvex") {
        fig1_nonconvex(scale, true)
    } else {
        fig1_convex(scale, true)
    };
    let arms = &exp.runs[2..]; // [DiveBatch, Oracle]
    println!("== Figure 2: Oracle vs DiveBatch ({}) ==\n", if args.flag("nonconvex") { "nonconvex" } else { "convex" });

    let rt = Runtime::load_default()?;
    let mut batch_series = Vec::new();
    let mut loss_series = Vec::new();
    let mut div_series = Vec::new();
    // Both arms through one engine pool, concurrently.
    let specs: Vec<TrialSpec> = arms.iter().flat_map(TrialSpec::expand).collect();
    let results = TrialRunner::new(args.usize("jobs")).run(&rt, &specs);
    for res in results {
        let rec = res.map_err(anyhow::Error::new)?;
        eprintln!("done: {}", rec.label);
        batch_series.push(Series::new(&rec.label, rec.batch_size_curve()));
        loss_series.push(Series::new(&rec.label, rec.val_loss_curve()));
        let curve = if rec.policy_kind == "oracle" {
            rec.exact_delta_curve()
        } else {
            rec.delta_hat_curve()
        };
        let label = if rec.policy_kind == "oracle" {
            "exact Delta (Oracle)"
        } else {
            "estimated Delta (DiveBatch)"
        };
        div_series.push(Series::new(label, curve));
    }
    println!("{}", render("validation loss", "epoch", &loss_series, 72, 12));
    println!(
        "{}",
        render("batch size progression", "epoch", &batch_series, 72, 12)
    );
    println!(
        "{}",
        render(
            "gradient diversity (estimated vs exact)",
            "epoch",
            &div_series,
            72,
            12
        )
    );
    Ok(())
}
