//! Quickstart: train a logistic-regression model on the paper's synthetic
//! dataset with DiveBatch and watch the batch size adapt to gradient
//! diversity.
//!
//! ```bash
//! make artifacts            # once
//! cargo run --release --example quickstart
//! ```

use divebatch::cluster::ClusterModel;
use divebatch::config::flops_per_sample;
use divebatch::coordinator::{LrSchedule, Policy, TrainConfig, Trainer};
use divebatch::data::{synthetic, SyntheticSpec};
use divebatch::runtime::Runtime;
use divebatch::util::plot::{render, Series};

fn main() -> anyhow::Result<()> {
    // 1. The runtime: loads artifacts/manifest.json and compiles the AOT
    //    HLO entries on first use.  Python is not involved.
    let rt = Runtime::load_default()?;
    println!("PJRT platform: {}", rt.platform());

    // 2. Data: Eq. 3 synthetic (x ~ U[-1,1]^512, noisy linear labels).
    let (train, val) = synthetic::generate(&SyntheticSpec {
        n: 4_000,
        d: 512,
        noise: 0.1,
        seed: 0,
    })
    .split(0.8);
    println!("dataset: {} train / {} val", train.n(), val.n());

    // 3. DiveBatch policy (Algorithm 1): start small, grow with measured
    //    gradient diversity, capped at 4096; Goyal lr rescaling on.
    let policy = Policy::DiveBatch {
        m0: 128,
        delta: 1.0,
        m_max: 4096,
    };
    let mut cfg = TrainConfig::new(
        "logreg512",
        policy,
        LrSchedule::step_075_20(16.0, true),
        20,
    );
    cfg.verbose = true;

    // 4. Train.
    let info = rt.model("logreg512")?;
    let cluster = ClusterModel::a100x4(info.param_count, flops_per_sample("logreg512"));
    let outcome = Trainer::new(&rt, cfg, train, val, cluster)?.run()?;
    let rec = outcome.record;

    // 5. Inspect: batch-size trajectory + accuracy curve.
    println!(
        "\n{}",
        render(
            "batch size per epoch (DiveBatch adapts via Definition 2)",
            "epoch",
            &[Series::new("m_k", rec.batch_size_curve())],
            64,
            10,
        )
    );
    println!(
        "{}",
        render(
            "validation accuracy",
            "epoch",
            &[Series::new("val acc %", rec.val_acc_curve())],
            64,
            10,
        )
    );
    println!(
        "final: val acc {:.2}%  end batch {}  est. diversity {:.3e}",
        rec.final_val_acc(),
        rec.end_batch_size(),
        rec.epochs.last().unwrap().delta_hat.unwrap_or(f64::NAN),
    );
    println!("\nstage profile:\n{}", outcome.profile.report());
    Ok(())
}
