//! Write-your-own batch policy in ~30 lines.
//!
//! Demonstrates the open `BatchPolicy` API: a plateau-triggered batch
//! grower defined *in this file* — no edits to `trainer.rs`, `args.rs`,
//! or anything else in the crate — trained head-to-head against a
//! registry-parsed wrapped DiveBatch spec.
//!
//! ```bash
//! make artifacts            # once
//! cargo run --release --example custom_policy
//! ```

use divebatch::cluster::ClusterModel;
use divebatch::config::flops_per_sample;
use divebatch::coordinator::{LrSchedule, PolicyRegistry, TrainConfig, Trainer};
use divebatch::data::{synthetic, SyntheticSpec};
use divebatch::runtime::Runtime;
use divebatch::util::plot::{render, Series};
use divebatch::{AdaptContext, BatchPolicy, Decision, DiversityNeed, PolicyError, PolicyHandle};

/// Double the batch size whenever validation loss stops improving by at
/// least `tol` — no gradient-diversity instrumentation needed, just the
/// loss history the trainer already exposes in [`AdaptContext`].
#[derive(Clone, Copy, Debug)]
struct Plateau {
    m0: usize,
    m_max: usize,
    tol: f64,
}

impl BatchPolicy for Plateau {
    fn kind(&self) -> &'static str {
        "plateau"
    }
    fn label(&self) -> String {
        format!("Plateau ({} - {})", self.m0, self.m_max)
    }
    fn initial(&self) -> usize {
        self.m0
    }
    fn on_epoch_end(&mut self, ctx: &AdaptContext) -> Result<Decision, PolicyError> {
        let stalled = match ctx.history {
            [.., prev, last] => prev.val_loss - last.val_loss < self.tol,
            _ => false,
        };
        let next = if stalled {
            (ctx.batch_size * 2).min(self.m_max)
        } else {
            ctx.batch_size
        };
        Ok(Decision::new(next, DiversityNeed::None))
    }
    fn render_spec(&self) -> String {
        format!("plateau:m0={},mmax={},tol={}", self.m0, self.m_max, self.tol)
    }
    fn clone_box(&self) -> Box<dyn BatchPolicy> {
        Box::new(*self)
    }
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default()?;
    let (train, val) = synthetic::generate(&SyntheticSpec {
        n: 4_000,
        d: 512,
        noise: 0.1,
        seed: 0,
    })
    .split(0.8);

    // Arm 1: the custom policy, boxed straight into TrainConfig.
    let plateau = PolicyHandle::new(Box::new(Plateau {
        m0: 128,
        m_max: 4096,
        tol: 1e-3,
    }));
    // Arm 2: a wrapped built-in via the registry spec grammar
    // (EMA-smoothed DiveBatch clamped to the same range).
    let wrapped = PolicyRegistry::builtin()
        .parse("clamp:min=128,max=4096/ema:beta=0.5/divebatch:m0=128,delta=1,mmax=4096")
        .map_err(anyhow::Error::new)?;

    let mut curves = Vec::new();
    for policy in [plateau, wrapped] {
        let label = policy.label();
        let mut cfg = TrainConfig::new(
            "logreg512",
            policy,
            LrSchedule::step_075_20(16.0, true),
            20,
        );
        cfg.verbose = true;
        let info = rt.model("logreg512")?;
        let cluster = ClusterModel::a100x4(info.param_count, flops_per_sample("logreg512"));
        let rec = Trainer::new(&rt, cfg, train.clone(), val.clone(), cluster)?
            .run()?
            .record;
        println!(
            "{label}: final val acc {:.2}%  end batch {}",
            rec.final_val_acc(),
            rec.end_batch_size()
        );
        curves.push(Series::new(&label, rec.batch_size_curve()));
    }
    println!(
        "\n{}",
        render("batch size per epoch", "epoch", &curves, 64, 12)
    );
    Ok(())
}
