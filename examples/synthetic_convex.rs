//! Figure 1 (top row) at example scale: convex synthetic experiment
//! comparing small-batch SGD, large-batch SGD, and DiveBatch on logistic
//! regression — the workload the paper's section 5.1 uses to show that
//! diversity-driven batch growth matches small-batch accuracy at
//! large-batch epoch cost.
//!
//! ```bash
//! cargo run --release --example synthetic_convex [-- --epochs 40 --n 20000]
//! ```

use divebatch::config::presets::{fig1_convex, Scale};
use divebatch::runtime::Runtime;
use divebatch::util::args::ArgSpec;
use divebatch::util::plot::{render, Series};
use divebatch::util::stats;
use divebatch::util::table::{pm, Table};

fn main() -> anyhow::Result<()> {
    let args = ArgSpec::new("synthetic_convex", "Figure 1 convex at example scale")
        .opt("epochs", Some("24"), "epochs per run")
        .opt("n", Some("4000"), "synthetic dataset size")
        .opt("trials", Some("1"), "trials per arm")
        .opt("jobs", Some("0"), "trial-engine worker threads (0 = all cores)")
        .parse_or_exit();

    let scale = Scale {
        epochs: args.usize("epochs"),
        trials: args.usize("trials"),
        n_synth: args.usize("n"),
        per_class: 0,
        ..Scale::quick()
    };
    let exp = fig1_convex(scale, false);
    println!("== {} ==\n", exp.title);

    let rt = Runtime::load_default()?;
    let mut loss_series = Vec::new();
    let mut acc_series = Vec::new();
    let mut table = Table::new(
        "validation accuracy at fraction of training",
        &["arm", "25%", "50%", "100%", "end m"],
    );
    for run in &exp.runs {
        let records = run.run_jobs(&rt, args.usize("jobs"))?;
        let label = records[0].label.clone();
        eprintln!("done: {label}");
        let losses: Vec<Vec<f64>> = records.iter().map(|r| r.val_loss_curve()).collect();
        let accs: Vec<Vec<f64>> = records.iter().map(|r| r.val_acc_curve()).collect();
        loss_series.push(Series::new(&label, stats::mean_curve(&losses)));
        acc_series.push(Series::new(&label, stats::mean_curve(&accs)));
        let at = |f: f64| -> Vec<f64> { records.iter().map(|r| r.val_acc_at_frac(f)).collect() };
        table.row(vec![
            label,
            pm(stats::mean(&at(0.25)), stats::stderr(&at(0.25))),
            pm(stats::mean(&at(0.5)), stats::stderr(&at(0.5))),
            pm(stats::mean(&at(1.0)), stats::stderr(&at(1.0))),
            format!("{}", records[0].end_batch_size()),
        ]);
    }
    println!("{}", render("validation loss", "epoch", &loss_series, 72, 14));
    println!("{}", render("validation accuracy", "epoch", &acc_series, 72, 14));
    println!("{}", table.render());
    Ok(())
}
